"""End-to-end DeFT pipeline (Profiler -> Solver -> Preserver) over the
real architecture configs — the paper's Fig. 7 loop."""
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.core.deft import plan_deft
from repro.core.policies import ALL_BASELINES
from repro.core.profiler import HardwareModel, profile_arch
from repro.core.scheduler import DeftScheduler
from repro.core.simulator import simulate_baseline, simulate_deft


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_plan_deft_runs_for_every_arch(arch):
    cfg = get_config(arch)
    plan = plan_deft(cfg, seq_len=4096, per_device_batch=1)
    assert plan.schedule.period >= 1
    assert plan.profile.times.n >= 1
    assert plan.retries <= 10
    # schedule must make progress
    assert plan.schedule.updates_per_period >= 1


def test_profile_coverage_rates_ordering():
    """High-compute archs (MoE at active params) should profile a lower CR
    than parameter-heavy dense nets at the same hardware model — mirrors
    the paper's Table I (GPT-2 CR < VGG-19 CR)."""
    hw = HardwareModel(dp_degree=16)
    cr = {
        a: profile_arch(get_config(a), hw=hw, seq_len=4096).coverage_rate
        for a in ("gemma2-2b", "starcoder2-7b")
    }
    # per-token compute grows faster than comm for bigger d_model
    assert cr["starcoder2-7b"] < cr["gemma2-2b"]


def test_preserver_feedback_reduces_merging():
    """When the Preserver rejects (tight eps), the capacity grows and the
    schedule syncs more per iteration."""
    cfg = get_config("gemma2-2b")
    hw = HardwareModel(dp_degree=16, ici_bw=3e9)   # force a high CR
    loose = plan_deft(cfg, hw=hw, seq_len=4096, eps=1e9)
    tight = plan_deft(cfg, hw=hw, seq_len=4096, eps=1e-6, max_retries=6)
    assert tight.capacity_factor >= loose.capacity_factor
    assert tight.schedule.update_frequency >= loose.schedule.update_frequency


def test_simulated_speedup_paper_regime():
    """Reproduce the paper's qualitative result on an assigned arch whose
    CR lands in the VGG-like regime: DeFT >= US-Byte >= ~DDP."""
    cfg = get_config("gemma2-2b")
    hw = HardwareModel(dp_degree=16, ici_bw=2.5e9)  # ethernet-like ratio
    plan = plan_deft(cfg, hw=hw, seq_len=4096)
    times = plan.profile.times
    assert times.coverage_rate > 1.0
    r_deft = simulate_deft(
        times, DeftScheduler(times, plan.scheduler_cfg).run(32)
    )
    speedups = {}
    for name, mk in ALL_BASELINES.items():
        r = simulate_baseline(times, mk(times))
        speedups[name] = r.iteration_time / r_deft.iteration_time
    assert all(s >= 0.99 for s in speedups.values()), speedups
    assert speedups["pytorch-ddp"] > 1.05
