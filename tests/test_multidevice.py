"""True multi-device checks, run in a subprocess with 8 forced host
devices (the test process itself must keep the real single-device view —
see the dry-run instructions about not forcing device counts globally)."""
import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import solve_schedule
from repro.core.scheduler import SchedulerConfig
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.models.model import loss_fn
from repro.optim.optimizers import adamw, apply_updates, init_opt_state
from repro.train import (DeftRuntime, assign_buckets, build_bucket_layout,
                         init_train_state, leaf_bucket_times)

# jaxlib < 0.5 hard-CHECKs (hlo_sharding_util.cc IsManualSubgroup) when a
# partial-manual region carries real tensor-parallel constraints on the
# auto axis; a size-1 model axis keeps the partitioner out of the buggy
# path while still exercising true 4-way data-parallel collectives.
_v = tuple(int(x) for x in jax.__version__.split(".")[:2])
mesh = jax.make_mesh((4, 2) if _v >= (0, 5) else (4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = reduce_for_smoke(get_config("qwen3-4b"))
opt = adamw(1e-3)
key = jax.random.PRNGKey(0)
probe = init_train_state(key, cfg, opt)
bucket_of, nb = assign_buckets(probe["params"], cfg, partition_elems=150_000)
hw = HardwareModel(dp_degree=4)
B, S = 8, 32
times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb, hw, S, 2)
scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
times = BucketTimes(times.fwd, times.bwd, tuple(c * scale for c in times.comm))
sched = solve_schedule(times, SchedulerConfig())
assert sched.updates_per_period < sched.period, "want a merging schedule"

# ---- fused DeftRuntime (production path): bucket-fused psums over the
# real 4-way data axis + donation, vs the grad-accumulation reference ----
layout = build_bucket_layout(probe["params"], bucket_of, nb)
ref_params = probe["params"]
ref_opt = init_opt_state(opt, ref_params)
zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             ref_params)
ref_cur, ref_fut = zeros(), zeros()
gfn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))

with mesh:
    rt = DeftRuntime(cfg, opt, sched, layout, mesh)
    state = rt.init_state(key)
    rt.compile(state, make_batch(cfg, 0, 0, B, S))
    for step in range(2 * sched.period):
        batch = make_batch(cfg, 0, step, B, S)
        ph = sched.phases[step % sched.period]
        prev = state
        state, m = rt.step(step, state, batch)
        assert all(x.is_deleted() for x in jax.tree.leaves(prev)), \
            "donation must hold on the multi-device mesh"
        g = gfn(ref_params, batch)
        if ph.rotate:
            gen = jax.tree.map(lambda a, b: a.astype(jnp.float32) + b, g,
                               ref_fut)
            ref_fut = jax.tree.map(jnp.zeros_like, ref_fut)
        else:
            ref_fut = jax.tree.map(lambda f, a: f + a.astype(jnp.float32),
                                   ref_fut, g)
            gen = None
        if ph.do_update:
            src = ref_cur if ph.update_source == "cur" else gen
            ref_params, ref_opt = apply_updates(
                opt, ref_params, src, ref_opt, grad_scale=1.0 / ph.update_k)
            ref_cur = gen if ph.update_source == "cur" else \
                jax.tree.map(jnp.zeros_like, ref_cur)
        elif ph.rotate:
            ref_cur = gen
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(rt.params_tree(state)),
                                   jax.tree.leaves(ref_params)))
        assert diff < 1e-4, f"step {step}: diverged by {diff}"

# ---- DeFT-RS (manual over 'pod', FSDP arch) lowers + runs at small scale.
# jaxlib < 0.5 aborts with an XLA SPMD CHECK (hlo_sharding_util.cc
# IsManualSubgroup) on ANY partial-manual + FSDP-constraint graph — an
# upstream partitioner bug, so the section is gated on the jax version
# (the 512-device production lowering hits a similar CHECK — upstream). --
_v = tuple(int(x) for x in jax.__version__.split(".")[:2])
if _v >= (0, 5):
    mesh_rs = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg_rs = reduce_for_smoke(get_config("deepseek-v2-236b"))
    probe_rs = init_train_state(jax.random.PRNGKey(5), cfg_rs, opt)
    bo_rs, nb_rs = assign_buckets(probe_rs["params"], cfg_rs,
                                  partition_elems=150_000)
    t_rs = leaf_bucket_times(probe_rs["params"], cfg_rs, bo_rs, nb_rs,
                             HardwareModel(dp_degree=2), 32, 4)
    t_rs = BucketTimes(t_rs.fwd, t_rs.bwd,
                       tuple(c * 50 for c in t_rs.comm))
    sched_rs = solve_schedule(t_rs, SchedulerConfig())
    # sharded flat engine (the fsdp default): layout split into 2 spans
    # to match the mesh's 2-way 'data' axis
    lay_rs = build_bucket_layout(probe_rs["params"], bo_rs, nb_rs,
                                 shard_count=2)
    with mesh_rs:
        rt_rs = DeftRuntime(cfg_rs, opt, sched_rs, lay_rs, mesh_rs, fsdp=True)
        assert rt_rs.flat_state, "fsdp now defaults to the sharded engine"
        state_rs = rt_rs.init_state(jax.random.PRNGKey(5))
        for step in range(min(sched_rs.period + 1, 4)):
            b_rs = make_batch(cfg_rs, 0, step, 8, 32)
            state_rs, m_rs = rt_rs.step(step, state_rs, b_rs)
            assert jnp.isfinite(m_rs["loss"])
    # the tree-state RS path (flat_state=False) stays available and is
    # exercised against the flat engine in test_flat_fsdp.py
else:
    print("RS section skipped: jaxlib SPMD partial-manual CHECK bug "
          f"(jax {jax.__version__})")

# ---- sharded flash-decode (distributed softmax) vs oracle ----
import numpy as np
from repro.kernels.flash_attention.sharded_decode import sharded_flash_decode
from repro.kernels.flash_attention.ref import attention_reference
mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
key = jax.random.PRNGKey(3)
q = jax.random.normal(key, (4, 1, 8, 16))
k = jax.random.normal(jax.random.fold_in(key, 1), (4, 64, 2, 16))
v = jax.random.normal(jax.random.fold_in(key, 2), (4, 64, 2, 16))
length = jnp.asarray([13, 64, 1, 40], jnp.int32)
with jax.set_mesh(mesh2):
    out = jax.jit(
        lambda q, k, v, l: sharded_flash_decode(q, k, v, l, softcap=30.0)
    )(q, k, v, length)
want = attention_reference(q, k, v, causal=False, softcap=30.0,
                           kv_length=length)
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           atol=2e-5, rtol=2e-5)
print("MULTIDEVICE_OK")
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_deft_equivalence_on_8_devices(tmp_path):
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script), src],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MULTIDEVICE_OK" in out.stdout
