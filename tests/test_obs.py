"""Unified trace/metrics layer (DESIGN.md §11): ring-buffer tracer with
injectable clock, Chrome-trace export determinism, SimResult->spans->
attribution closure against the simulator's own numbers, the live
divergence signal leading the EMA drift trigger, the runtime's swap_log
compat shim, the first-dispatch cold tag, and the <2% tracing-overhead
bound on fused smoke dispatch."""
import dataclasses
import json
import math
import random
import statistics

import jax
import jax.numpy as jnp
import pytest

from repro.adapt import (
    AdaptConfig,
    AdaptiveController,
    BandwidthDrop,
    SyntheticTelemetrySource,
    Telemetry,
    TelemetryConfig,
    run_control_loop,
    scale_times,
)
from repro.adapt.calibrate import planned_phase_durations
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import feedback_solve
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.core.scheduler import DeftScheduler
from repro.core.simulator import simulate_deft
from repro.data.pipeline import make_batch
from repro.elastic import HealthConfig, HealthMonitor
from repro.models.model import init_params
from repro.obs import (
    Attribution,
    ManualClock,
    Metrics,
    METRICS_SCHEMA_VERSION,
    SPAN_KINDS,
    Span,
    Tracer,
    attribute,
    attribute_trace,
    format_event,
    latest_phase_durations,
    measured_phase_durations_from_trace,
    phase_divergence,
    sim_metrics_from_spans,
    spans_from_sim,
    timeline_bubbles,
    validate_summary,
)
from repro.optim.optimizers import adamw
from repro.train import (
    DeftRuntime,
    assign_buckets,
    build_bucket_layout,
    leaf_bucket_times,
)

WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)


def _toy_times(n=8, cr=1.8, seed=0):
    rng = random.Random(seed)
    fwd = tuple(rng.uniform(0.002, 0.02) for _ in range(n))
    bwd = tuple(2 * f for f in fwd)
    comm = tuple(rng.uniform(0.005, 0.08) for _ in range(n))
    t = BucketTimes(fwd, bwd, comm)
    scale = cr * (t.fwd_total + t.bwd_total) / t.comm_total
    return BucketTimes(fwd, bwd, tuple(c * scale for c in comm))


# ---------------------------------------------------------------------------
# Tracer: ring bound, injectable clock, deterministic export
# ---------------------------------------------------------------------------
def test_tracer_ring_bound_and_stats():
    tr = Tracer(capacity=4, clock=ManualClock())
    for i in range(10):
        tr.instant("replan", f"e{i}", step=i)
    assert len(tr) == 4
    st = tr.stats()
    assert st["recorded"] == 10 and st["retained"] == 4
    assert st["dropped"] == 6
    assert st["by_kind"] == {"replan": 4}
    # the ring keeps the NEWEST spans
    assert [s.name for s in tr.spans()] == ["e6", "e7", "e8", "e9"]


def test_tracer_rejects_unknown_kind():
    tr = Tracer(capacity=8)
    with pytest.raises(ValueError):
        tr.add("not-a-kind", "x", 0.0, 1.0)
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_tracer_span_contextmanager_uses_clock_and_survives_raise():
    clk = ManualClock()
    tr = Tracer(capacity=8, clock=clk)
    with tr.span("repack", "ok", step=3):
        clk.advance(0.5)
    with pytest.raises(RuntimeError):
        with tr.span("repack", "boom"):
            clk.advance(0.25)
            raise RuntimeError("x")
    spans = tr.spans("repack")
    assert [s.name for s in spans] == ["ok", "boom"]
    assert spans[0].duration == pytest.approx(0.5)
    assert spans[1].duration == pytest.approx(0.25)


def test_tracer_spans_filter_accepts_str_or_iterable():
    tr = Tracer(capacity=8, clock=ManualClock())
    tr.instant("replan", "a")
    tr.instant("repack", "b")
    tr.instant("elastic", "c")
    assert [s.name for s in tr.spans("repack")] == ["b"]
    assert [s.name for s in tr.spans(("replan", "elastic"))] == ["a", "c"]


def _replayed_trace():
    """One deterministic synthetic run under an injected clock."""
    clk = ManualClock()
    tr = Tracer(capacity=64, clock=clk)
    for step in range(5):
        t0 = clk()
        clk.advance(0.010 + step * 0.001)
        tr.add("phase", f"phase{step % 2}", t0, clk(),
               step=step, phase=step % 2, first=(step == 0))
        tr.add("step", f"step{step}", t0, clk(), step=step)
    tr.instant("swap-install", "swap-install", step=4, period=2,
               updates_per_period=1, n_buckets=3, shards=1, repack_s=None)
    return tr


def test_trace_replay_bit_match(tmp_path):
    """Identical injected-clock replays export byte-identical traces."""
    p1, p2 = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    pay1 = _replayed_trace().export_chrome_trace(p1)
    pay2 = _replayed_trace().export_chrome_trace(p2)
    assert pay1 == pay2
    assert open(p1, "rb").read() == open(p2, "rb").read()


def test_chrome_export_is_perfetto_shaped(tmp_path):
    path = str(tmp_path / "t.json")
    tr = _replayed_trace()
    tr.export_chrome_trace(path, extra={"note": "test"})
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} >= {"steps", "phases"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all("dur" in e and "ts" in e and "cat" in e for e in xs)
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and all(e["s"] == "t" for e in inst)
    # seconds -> microseconds
    ph0 = next(e for e in xs if e["cat"] == "phase")
    assert ph0["dur"] == pytest.approx(0.010 * 1e6)
    assert doc["otherData"]["dropped_spans"] == 0
    assert doc["otherData"]["note"] == "test"


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
def test_metrics_counters_gauges_and_jsonl(tmp_path):
    m = Metrics()
    m.inc("replans")
    m.inc("replans")
    m.inc("spans", by=5)
    m.set("coverage_rate", 1.8)
    m.set("coverage_rate", 2.0)       # gauge holds the latest
    assert m.counter("replans") == 2
    assert m.counter("missing") == 0
    assert m.gauge("coverage_rate") == 2.0
    assert m.gauge("missing") is None

    s = m.summary()
    validate_summary(s)
    assert s["schema"] == METRICS_SCHEMA_VERSION
    assert s["counters"] == {"replans": 2, "spans": 5}
    assert s["gauges"] == {"coverage_rate": 2.0}

    path = str(tmp_path / "m.jsonl")
    m.export_jsonl(path)
    m.inc("replans")
    m.export_jsonl(path, extra={"step": 7})
    lines = open(path).read().splitlines()
    assert len(lines) == 2
    last = json.loads(lines[-1])
    validate_summary(last)
    assert last["counters"]["replans"] == 3 and last["extra"]["step"] == 7


def test_validate_summary_rejects_bad_payloads():
    with pytest.raises(ValueError):
        validate_summary({"schema": METRICS_SCHEMA_VERSION})
    with pytest.raises(ValueError):
        validate_summary({"schema": 999, "counters": {}, "gauges": {}})
    with pytest.raises(ValueError):
        validate_summary(
            {"schema": METRICS_SCHEMA_VERSION, "counters": [], "gauges": {}}
        )


# ---------------------------------------------------------------------------
# Closure: SimResult -> spans -> the simulator's own numbers
# ---------------------------------------------------------------------------
def _deft_sim(times, n_iters=24):
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    sched = DeftScheduler(times, scfg)
    plans = sched.run(n_iters)
    sim = simulate_deft(times, plans, mu=scfg.mu,
                        heterogeneous=scfg.heterogeneous,
                        keep_timeline=True)
    return sim, scfg, schedule


def test_sim_span_closure_reproduces_simulator_numbers():
    times = _toy_times()
    sim, scfg, _ = _deft_sim(times)
    spans = spans_from_sim(sim)
    m = sim_metrics_from_spans(spans, mu=scfg.mu)
    # iteration time is bit-exact (same subtraction the simulator does)
    assert m.iteration_time == sim.iteration_time
    assert m.bubble_fraction == pytest.approx(sim.bubble_fraction,
                                              rel=1e-9, abs=1e-12)
    # compute reconstructed from F/B spans == the profile totals
    assert m.compute_time == pytest.approx(
        times.fwd_total + times.bwd_total, rel=1e-9
    )
    # per-bucket nominal comm matches the profile (merging never grows a
    # tensor, so any occurrence carries the bucket's nominal cost)
    for b, c in m.per_bucket_comm.items():
        assert c == pytest.approx(times.comm[b], rel=1e-9)
    assert m.coverage_rate == pytest.approx(times.coverage_rate, rel=1e-9)
    assert 0.0 <= m.bubble_fraction < 1.0


def test_spans_from_sim_requires_timeline():
    times = _toy_times(n=4)
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = DeftScheduler(times, scfg).run(8)
    sim = simulate_deft(times, plans, mu=scfg.mu,
                        heterogeneous=scfg.heterogeneous)
    with pytest.raises(ValueError):
        spans_from_sim(sim)


def test_timeline_bubbles_attributes_idle_to_collectives():
    # compute busy [0,1] and [2,3]; a bucket-7 collective covers the
    # idle gap [1,2]; a bucket-1 collective overlaps busy time only
    spans = [
        Span("compute", "F0@0", 0.0, 1.0),
        Span("compute", "B0@0", 2.0, 3.0),
        Span("collective", "C7", 0.8, 2.0, attrs=(("bucket", 7), ("link", 0))),
        Span("collective", "C1", 0.2, 0.9, attrs=(("bucket", 1), ("link", 1))),
    ]
    idle, exposed, busy = timeline_bubbles(spans, 0.0, 3.0)
    assert idle == pytest.approx(1.0)
    assert exposed == {7: pytest.approx(1.0)}
    assert busy[0] == pytest.approx(1.2) and busy[1] == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# Live attribution: measured vs plan
# ---------------------------------------------------------------------------
def test_attribution_undisturbed_run_matches_plan():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    planned = planned_phase_durations(times, scfg, schedule.period)
    att = attribute(planned, times, scfg, schedule)
    assert isinstance(att, Attribution)
    # measuring exactly the plan: identity scales, ~zero divergence
    assert att.comp_scale == pytest.approx(1.0, abs=0.02)
    assert att.comm_scale == pytest.approx(1.0, abs=0.02)
    assert att.max_divergence < 1e-9
    assert att.cr_error < 0.05
    assert att.measured_cr == pytest.approx(times.coverage_rate, rel=0.05)
    assert att.iteration_time > 0 and 0 <= att.bubble_fraction < 1
    # the knapsack never over-fills its capacity windows by much more
    # than the simulator's overflow spill
    assert att.capacity_utilization["link0"] > 0
    for v in att.capacity_utilization.values():
        assert v < 2.0


def test_attribution_degraded_run_flags_comp_scale():
    # a compute slowdown lengthens every phase monotonically, so the
    # fit is well identified (a comm slowdown is not: missed collective
    # windows turn into gather-skips and phases SHORTEN — see §11)
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    degraded = scale_times(times, 1.6, 1.0)
    measured = planned_phase_durations(degraded, scfg, schedule.period)
    att = attribute(measured, times, scfg, schedule)
    assert att.comp_scale > 1.3          # the compute axis took the hit
    assert att.comm_scale == pytest.approx(1.0, abs=0.35)
    assert att.measured_cr < times.coverage_rate
    assert att.max_divergence > 0.1
    # every bucket syncs inside some slipped phase, so all diverge
    assert att.per_bucket_divergence
    assert max(att.per_bucket_divergence.values()) > 0.05


def test_phase_divergence_and_latest_samples():
    planned = [1.0, 2.0]
    assert phase_divergence(planned, [1.1, None]) == (
        pytest.approx(0.1), None,
    )
    tel = Telemetry(2, TelemetryConfig(warmup_steps=0))
    tel.record(0, 0, 1.0)
    tel.record(1, 1, 2.0)
    tel.record(2, 0, 3.0)              # newest sample wins
    assert latest_phase_durations(tel.samples(), 2) == [3.0, 2.0]


def test_attribute_trace_excludes_first_dispatch_spans():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    period = schedule.period
    planned = planned_phase_durations(times, scfg, period)
    clk = ManualClock()
    tr = Tracer(capacity=256, clock=clk)
    for step in range(3 * period):
        p = step % period
        # first cycle is compile-polluted: 50x the planned duration
        dur = planned[p] * (50.0 if step < period else 1.0)
        t0 = clk()
        clk.advance(dur)
        tr.add("phase", f"phase{p}", t0, clk(), step=step, phase=p,
               first=(step < period))
    measured = measured_phase_durations_from_trace(tr, period)
    for p in range(period):
        assert measured[p] == pytest.approx(planned[p], rel=1e-9)
    att = attribute_trace(tr, times, scfg, schedule)
    assert att.max_divergence < 1e-6   # pollution fully excluded


# ---------------------------------------------------------------------------
# Divergence leads the EMA drift trigger
# ---------------------------------------------------------------------------
_DROP_STEP = 24
_DROP_SCALE = 1.9      # phase slip in (threshold, EMA-instant) band


def _drop_controller(drift_source):
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=_DROP_STEP, comm_scale=_DROP_SCALE)
    )
    ctrl = AdaptiveController(
        times, schedule, scfg, walk=WALK,
        cfg=AdaptConfig(warmup_steps=4, check_every=1, cooldown_steps=8,
                        min_loss_samples=10**9, drift_source=drift_source),
    )
    return ctrl, src, times, schedule, scfg


def test_divergence_flags_drop_before_ema_trigger():
    """The attribution divergence crosses the drift threshold strictly
    before the legacy EMA screen replans — the acceptance property."""
    ctrl, src, times, schedule, scfg = _drop_controller("ema")
    planned = planned_phase_durations(times, scfg, schedule.period)
    flagged = None
    phase = 0
    ema_step = None
    for step in range(3 * _DROP_STEP):
        wall = src.wall_time(step, ctrl.schedule, ctrl.scheduler_cfg,
                             phase, solve_times=ctrl.times)
        ev = ctrl.observe(step, phase, wall)
        phase = (phase + 1) % schedule.period
        if flagged is None:
            div = phase_divergence(
                planned,
                latest_phase_durations(ctrl.telemetry.samples(),
                                       schedule.period),
            )
            if max((abs(d) for d in div if d is not None), default=0.0) \
                    > ctrl.cfg.drift_threshold:
                flagged = step
        if ev is not None:
            ema_step = step
            break
    assert ema_step is not None, "EMA screen never triggered"
    assert flagged is not None and _DROP_STEP <= flagged < ema_step
    # and the full attribution report at the flag step names the drop
    att = attribute(
        latest_phase_durations(ctrl.telemetry.samples(), schedule.period),
        times, scfg, ctrl.schedule,
    )
    assert att.comm_scale > 1.1
    assert att.max_divergence > ctrl.cfg.drift_threshold


def test_divergence_drift_source_replans_no_later_than_ema():
    ctrl_e, src_e, *_ = _drop_controller("ema")
    ctrl_d, src_d, *_ = _drop_controller("divergence")
    run_control_loop(ctrl_e, src_e, 3 * _DROP_STEP)
    run_control_loop(ctrl_d, src_d, 3 * _DROP_STEP)
    assert ctrl_e.events and ctrl_d.events
    assert ctrl_d.events[0].step < ctrl_e.events[0].step
    # both tripped after the drop, on the timing path
    for ev in (ctrl_d.events[0], ctrl_e.events[0]):
        assert ev.step >= _DROP_STEP and ev.trigger == "timing-drift"


def test_controller_emits_replan_spans():
    ctrl, src, *_ = _drop_controller("divergence")
    tracer = Tracer(capacity=64, clock=ManualClock())
    ctrl.tracer = tracer
    run_control_loop(ctrl, src, 3 * _DROP_STEP)
    spans = tracer.spans("replan")
    assert len(spans) == len(ctrl.events)
    sp = spans[0]
    assert sp.name == "timing-drift" and sp.step == ctrl.events[0].step
    args = sp.args
    assert args["old_period"] == ctrl.events[0].old_period
    assert args["changed"] == ctrl.events[0].changed
    assert args["comm_scale"] > 1.0


# ---------------------------------------------------------------------------
# Telemetry cold tag (first-dispatch pollution fix)
# ---------------------------------------------------------------------------
def test_telemetry_cold_tag_replaces_fixed_warmup():
    tel = Telemetry(1, TelemetryConfig(warmup_steps=5))
    tel.record(0, 0, 100.0, cold=True)     # first dispatch: never enters
    assert tel.phase_time(0) is None
    tel.record(1, 0, 1.0, cold=False)      # tagged warm: enters at once
    assert tel.phase_time(0) == pytest.approx(1.0)
    # legacy behaviour (no tag) still honors the fixed count
    tel2 = Telemetry(1, TelemetryConfig(warmup_steps=5))
    tel2.record(0, 0, 1.0)
    assert tel2.phase_time(0) is None


def test_telemetry_cold_tag_respects_rebase_window():
    tel = Telemetry(1, TelemetryConfig(warmup_steps=0))
    tel.rebase(1, extra_warmup=2)
    # the old schedule's tail steps land inside the re-armed window even
    # when tagged warm — they ran under the OLD phase keys
    tel.record(0, 0, 9.0, cold=False)
    tel.record(1, 0, 9.0, cold=False)
    assert tel.phase_time(0) is None
    tel.record(2, 0, 1.0, cold=False)
    assert tel.phase_time(0) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# One formatter for every event surface
# ---------------------------------------------------------------------------
def test_format_event_all_surfaces():
    # swap install / failure dicts (the runtime's swap_log shapes)
    line = format_event({"step": 10, "period": 4, "updates_per_period": 1,
                         "n_buckets": 5, "shards": 2, "repack_s": 0.025})
    assert line.startswith("swap") and "period=4" in line
    assert "repack 25 ms" in line
    line = format_event({"step": None, "event": "swap-compile-failed",
                         "attempt": 1, "retrying": True, "error": "boom"})
    assert "compile-failed" in line and "retrying" in line
    line = format_event({"step": None, "event": "swap-abandoned",
                         "attempts": 3, "elapsed_s": 1.5,
                         "superseded": True, "error": "boom"})
    assert "ABANDONED" in line and "superseded" in line
    # elastic migration / halt dicts
    line = format_event({"step": 12, "action": "scale-down", "trigger":
                         "dead", "detected_step": 9, "old_shards": 4,
                         "new_shards": 3, "old_period": 2, "new_period": 3,
                         "migrate_s": 0.5, "repack_s": 0.1})
    assert line.startswith("elastic") and "4->3 shards" in line
    line = format_event({"step": 12, "action": "checkpoint-halt",
                         "trigger": "dead", "detected_step": 9,
                         "checkpoint": "/tmp/x"})
    assert "checkpoint-halt" in line
    # spans
    line = format_event(Span("repack", "repack-state", 0.0, 0.004, step=3,
                             attrs=(("moved_elems", 42),)))
    assert line.startswith("repack") and "4.00 ms" in line
    assert "moved_elems=42" in line
    # replan + fault events route through their describe()
    times = _toy_times(n=4)
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=8, comm_scale=3.0))
    ctrl = AdaptiveController(
        times, schedule, scfg, walk=WALK,
        cfg=AdaptConfig(warmup_steps=2, check_every=1, cooldown_steps=4,
                        min_loss_samples=10**9))
    run_control_loop(ctrl, src, 40)
    assert ctrl.events
    assert format_event(ctrl.events[0]).startswith("adapt")
    mon = HealthMonitor(2, HealthConfig(warmup_steps=0))
    ev = mon.notice_preemption(5, 1)
    assert format_event(ev).startswith("elastic")
    assert "event" in format_event(object())


def test_health_monitor_mirrors_detections_into_trace():
    tracer = Tracer(capacity=32, clock=ManualClock())
    mon = HealthMonitor(
        4, HealthConfig(warmup_steps=1, straggler_patience=2), tracer=tracer
    )
    mon.notice_preemption(4, 3)
    for i in range(8):
        walls = [0.1, 0.1 * (3.0 if i >= 2 else 1.0), 0.1, None]
        mon.observe(i, walls)
    names = [s.name for s in tracer.spans("elastic")]
    assert "detect-preemption" in names
    assert "detect-straggler" in names
    sp = next(s for s in tracer.spans("elastic")
              if s.name == "detect-straggler")
    assert sp.args["shard"] == 1 and sp.args["monitor_clock"] > 0


# ---------------------------------------------------------------------------
# Runtime integration: spans, swap_log shim, overhead bound
# ---------------------------------------------------------------------------
B, S = 4, 32


def _tiny_cfg():
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )


def _tiny_schedule(cfg, params):
    bucket_of, nb = assign_buckets(params, cfg, partition_elems=20_000)
    hw = HardwareModel(dp_degree=2)
    times = leaf_bucket_times(params, cfg, bucket_of, nb, hw, S, B)
    scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    layout = build_bucket_layout(params, bucket_of, nb)
    return times, schedule, scfg, layout


def test_runtime_trace_and_swap_log_shim(single_mesh):
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    times, schedule, scfg, layout = _tiny_schedule(cfg, params)
    tracer = Tracer(capacity=4096)
    runtime = DeftRuntime(cfg, adamw(1e-3), schedule, layout, single_mesh,
                          tracer=tracer)
    assert runtime.trace_steps and runtime.tracer is tracer
    state = runtime.init_state(key)

    # a second schedule to hot-swap to (comm 2x slower)
    new_schedule, _, _, _ = feedback_solve(
        scale_times(times, 1.0, 2.0), WALK
    )
    assert new_schedule.phases != schedule.phases
    n_steps = 2 * schedule.period + new_schedule.period
    with jax.set_mesh(single_mesh):
        for step in range(n_steps):
            state, m = runtime.step(step, state, make_batch(cfg, 0, step, B, S))
            if step == 0:
                assert runtime.last_dispatch_first        # cold tag
            if step == 2 * schedule.period - 1:
                # attribution over the undisturbed window: every phase
                # of the installed plan has an untagged (warm) sample
                att = attribute_trace(tracer, times, scfg, schedule)
                assert att.period == schedule.period
                assert all(mv is not None for mv in att.measured_phase_s)
                runtime.prepare_swap(new_schedule, state,
                                     make_batch(cfg, 0, 0, B, S),
                                     background=False)
        jax.block_until_ready(m["loss"])

    # per-step spans: one phase + one collective-group per dispatch,
    # first-dispatch tagging on exactly the unique executables
    phases = tracer.spans("phase")
    assert len(phases) == n_steps
    assert all(sp.phase is not None and sp.duration > 0 for sp in phases)
    firsts = [sp for sp in phases if sp.args.get("first")]
    assert firsts and firsts[0].step == 0
    assert len(tracer.spans("collective-group")) == n_steps

    # control-plane spans + the swap_log compat shim
    assert len(tracer.spans("swap-compile")) == 1
    installs = tracer.spans("swap-install")
    assert len(installs) == 1
    log = runtime.swap_log
    assert len(log) == 1
    entry = log[0]
    assert entry["step"] % schedule.period == 0
    assert entry["period"] == new_schedule.period
    assert entry["updates_per_period"] == new_schedule.updates_per_period
    assert entry["n_buckets"] == layout.n_buckets
    assert entry["shards"] == layout.shards
    assert entry["repack_s"] is None          # same layout: no repack
    assert runtime.stats()["trace"]["recorded"] == tracer.n_recorded

    # spawn() propagates the tracer when tracing is on
    assert runtime.spawn(schedule=schedule).tracer is tracer


def test_untraced_runtime_records_control_plane_only(single_mesh):
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    _, schedule, _, layout = _tiny_schedule(cfg, params)
    runtime = DeftRuntime(cfg, adamw(1e-3), schedule, layout, single_mesh)
    assert not runtime.trace_steps            # no per-step span cost
    assert runtime.swap_log == []             # shim on the internal tracer
    state = runtime.init_state(key)
    with jax.set_mesh(single_mesh):
        for step in range(2):
            state, m = runtime.step(step, state,
                                    make_batch(cfg, 0, step, B, S))
    assert runtime.tracer.spans("phase") == []


@pytest.mark.slow
def test_tracing_overhead_under_2_percent(single_mesh):
    """Dispatching with per-step tracing attached stays within 2% of the
    untraced fused dispatch rate (median of paired interleaved chunk
    ratios, best of 3 attempts)."""
    import time

    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    _, schedule, _, layout = _tiny_schedule(cfg, params)
    opt = adamw(1e-3)
    rt_plain = DeftRuntime(cfg, opt, schedule, layout, single_mesh)
    rt_traced = DeftRuntime(cfg, opt, schedule, layout, single_mesh,
                            tracer=Tracer(capacity=1 << 16))
    batch = make_batch(cfg, 0, 0, B, S)
    with jax.set_mesh(single_mesh):
        s_plain = rt_plain.init_state(key)
        s_traced = rt_traced.init_state(key)
        rt_plain.compile(s_plain, batch)
        rt_traced.compile(s_traced, batch)

        def timed(rt, state, n=40):
            t0 = time.perf_counter()
            for i in range(n):
                state, m = rt.step(i, state, batch)
            jax.block_until_ready(m["loss"])
            return time.perf_counter() - t0, state

        # warm both, then time paired rounds and take the MEDIAN of the
        # per-round traced/plain ratios: pairing shares each round's
        # ambient load between the two engines, alternating which goes
        # first cancels any systematic second-position penalty, and the
        # median kills rounds where a load spike hit only one side
        # (min-of-chunks is one-sided — a single anomalously fast plain
        # chunk sets a floor the traced side can never match).  On a
        # loaded single-core host even that flakes, so the measurement
        # retries up to 3 times: a genuine overhead regression shifts
        # every round of every attempt and still fails.
        _, s_plain = timed(rt_plain, s_plain, n=10)
        _, s_traced = timed(rt_traced, s_traced, n=10)
        overhead = math.inf
        for _attempt in range(3):
            ratios = []
            for r in range(9):
                if r % 2 == 0:
                    dp, s_plain = timed(rt_plain, s_plain)
                    dt, s_traced = timed(rt_traced, s_traced)
                else:
                    dt, s_traced = timed(rt_traced, s_traced)
                    dp, s_plain = timed(rt_plain, s_plain)
                ratios.append(dt / dp)
            overhead = min(overhead, statistics.median(ratios) - 1.0)
            if overhead < 0.02:
                break
    assert overhead < 0.02, (
        f"tracing overhead {overhead * 100:.2f}% >= 2% "
        f"(median of paired traced/plain chunk ratios, best of 3 attempts)"
    )
