"""Fused bucket-update engine (kernels/bucket_update): Pallas kernel vs
pure-JAX twin, flat path vs the per-leaf apply_updates reference, segment
maps, padded-tail masking, delayed-update staleness and donation.

Tolerance contract: the Pallas kernel and its lax twin compute the same
f32 expressions in the same order; residual differences are XLA FMA-
contraction noise (<= a few ulp), so kernel-level checks use tight
absolute tolerances and the tail (a where-select of untouched inputs)
must match bitwise.  With grad clipping off, the flat path is bitwise
against per-leaf apply_updates; with clipping on, the global-norm
reduction is grouped per bucket instead of per leaf (last-ulp clip
factor), so those checks are tight-tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import make_batch
from repro.kernels.bucket_update import (
    apply_bucket_updates,
    bucket_update_pallas,
    bucket_update_ref,
    build_segments,
    init_flat_opt_state,
    pack_scalars,
)
from repro.optim.optimizers import (
    adamw,
    apply_updates,
    init_opt_state,
    leaf_hparams,
    sgd_momentum,
)
from repro.train.bucketing import (
    build_bucket_layout,
    flatten_buckets,
    unflatten_buckets,
)

KTOL = 1e-6          # kernel-vs-twin: FMA-contraction noise only


def _tree():
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (37, 9)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (13,)),
        "h": jax.random.normal(jax.random.fold_in(key, 2), (200,)),
        "u": jax.random.normal(jax.random.fold_in(key, 3), (5, 7, 3)),
    }


def _layout(params):
    # tree_flatten order: b(13), h(200), u(105), w(333) -> odd tails
    return build_bucket_layout(params, (0, 1, 1, 0), 2)


SPECS = [
    adamw(1e-2, weight_decay=0.01),
    sgd_momentum(3e-2, momentum=0.85, weight_decay=0.02),
    adamw(1e-2, weight_decay=0.1, decay_mask="matrix", ndim1_lr_scale=0.5),
    sgd_momentum(1e-2, grad_clip=0.0),
]
SPEC_IDS = ["adamw", "sgd", "adamw-segmented", "sgd-noclip"]


# ---------------------------------------------------------------------------
# Pallas kernel (interpret) vs the lax twin — one bucket, odd tail
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("padded,n_valid", [(640, 533), (128, 128), (256, 1)])
@pytest.mark.parametrize("spec", SPECS[:2], ids=SPEC_IDS[:2])
def test_pallas_matches_ref_twin(spec, padded, n_valid):
    key = jax.random.PRNGKey(7)
    mk = lambda i: jax.random.normal(
        jax.random.fold_in(key, i), (padded,)
    ).at[n_valid:].set(0.0)
    p, m, g = mk(0), mk(1), mk(3)
    v = jnp.abs(mk(2)) if spec.name == "adamw" else None
    scal = pack_scalars(spec, jnp.int32(3), grad_scale=0.5,
                        clip=jnp.float32(0.9))
    kw = dict(n_valid=n_valid, uniform=(1.0, spec.weight_decay),
              zero_grads=True)
    ref = bucket_update_ref(spec, p, m, v, g, scal, **kw)
    got = bucket_update_pallas(spec, p, m, v, g, scal, interpret=True, **kw)
    for name, a, b in zip("pmv", ref, got):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=KTOL, rtol=KTOL, err_msg=name)
        # the masked tail is a passthrough select: bitwise
        assert bool(jnp.array_equal(a[n_valid:], b[n_valid:]))
    assert not np.any(np.asarray(got[3]))         # fused zeroing
    # odd tail stays at the input value (zero here)
    assert not np.any(np.asarray(got[0][n_valid:]))


@pytest.mark.parametrize("spec", SPECS[:2], ids=SPEC_IDS[:2])
def test_pallas_multiblock_grid(spec):
    """Row-blocked grid with a partial final block (10 rows, blocks of
    4) matches the twin — the tiling/index-map path, not just grid=1."""
    padded, n_valid = 1280, 1200
    key = jax.random.PRNGKey(11)
    mk = lambda i: jax.random.normal(
        jax.random.fold_in(key, i), (padded,)
    ).at[n_valid:].set(0.0)
    p, m, g = mk(0), mk(1), mk(3)
    v = jnp.abs(mk(2)) if spec.name == "adamw" else None
    scal = pack_scalars(spec, jnp.int32(2), grad_scale=1.0,
                        clip=jnp.float32(1.0))
    kw = dict(n_valid=n_valid, uniform=(1.0, spec.weight_decay))
    ref = bucket_update_ref(spec, p, m, v, g, scal, **kw)
    got = bucket_update_pallas(spec, p, m, v, g, scal, block_rows=4,
                               interpret=True, **kw)
    for name, a, b in zip("pmv", ref, got):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=KTOL, rtol=KTOL, err_msg=name)


def test_tail_garbage_is_masked():
    """Garbage riding the padded gradient tail must not leak into params
    or moments — the kernels mask on the static valid length."""
    spec = adamw(1e-2, weight_decay=0.05)
    padded, n_valid = 384, 300
    key = jax.random.PRNGKey(9)
    mk = lambda i: jax.random.normal(
        jax.random.fold_in(key, i), (padded,)
    ).at[n_valid:].set(0.0)
    p, m, v = mk(0), mk(1), jnp.abs(mk(2))
    g = mk(3).at[n_valid:].set(jnp.nan)           # hostile tail
    scal = pack_scalars(spec, jnp.int32(1), grad_scale=1.0,
                        clip=jnp.float32(1.0))
    for impl_kw in ({"interpret": True},):
        p2, m2, v2, _ = bucket_update_pallas(
            spec, p, m, v, g, scal, n_valid=n_valid, uniform=(1.0, 0.05),
            **impl_kw,
        )
        for new, old in ((p2, p), (m2, m), (v2, v)):
            assert bool(jnp.array_equal(new[n_valid:], old[n_valid:]))
            assert bool(jnp.all(jnp.isfinite(new[:n_valid])))
    r = bucket_update_ref(spec, p, m, v, g, scal, n_valid=n_valid,
                          uniform=(1.0, 0.05))
    assert bool(jnp.array_equal(r[0][n_valid:], p[n_valid:]))
    assert bool(jnp.all(jnp.isfinite(r[0][:n_valid])))


def test_tail_garbage_does_not_poison_clip_norm():
    """Regression: the global-norm clip in apply_bucket_updates must sum
    the VALID spans only — a NaN riding a padded gradient tail once
    leaked through the clip scalar into every valid parameter."""
    params = _tree()
    layout = _layout(params)
    spec = adamw(1e-2)                                 # grad_clip on
    assert any(layout.buf_sizes[b] > layout.sizes[b]
               for b in range(layout.n_buckets))
    seg = build_segments(layout, spec)
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
    gbuf = [g.at[layout.sizes[b]:].set(jnp.nan)
            for b, g in enumerate(flatten_buckets(
                layout, jax.tree.leaves(params)))]
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    new_p, _, _ = apply_bucket_updates(spec, seg, pbuf, gbuf, opt_f,
                                       grad_scale=1.0, impl="ref")
    for b in range(layout.n_buckets):
        assert bool(jnp.all(jnp.isfinite(new_p[b][:layout.sizes[b]])))


# ---------------------------------------------------------------------------
# Flat path vs per-leaf apply_updates (the numerical reference)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_flat_matches_per_leaf_reference(spec, impl):
    params = _tree()
    layout = _layout(params)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(42), p.shape), params
    )
    seg = build_segments(layout, spec)

    p_ref, o_ref = params, init_opt_state(spec, params)
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
    gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    for _ in range(4):
        p_ref, o_ref = apply_updates(spec, p_ref, grads, o_ref,
                                     grad_scale=0.25)
        pbuf, opt_f, _ = apply_bucket_updates(
            spec, seg, pbuf, gbuf, opt_f, grad_scale=0.25, impl=impl
        )
    got = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), unflatten_buckets(layout, pbuf)
    )
    exact = spec.grad_clip == 0.0 and impl == "ref"
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(p_ref)):
        if exact:
            assert bool(jnp.array_equal(a, b)), "noclip/ref must be bitwise"
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-6, rtol=5e-6)
    assert int(opt_f["step"]) == int(o_ref["step"]) == 4
    # padded tails never move
    for b_ in range(layout.n_buckets):
        tail = pbuf[b_][layout.sizes[b_]:]
        assert tail.size == 0 or not np.any(np.asarray(tail))


# ---------------------------------------------------------------------------
# Segment-id map
# ---------------------------------------------------------------------------
def test_segment_map_structure():
    params = _tree()
    layout = _layout(params)
    spec = adamw(1e-2, weight_decay=0.1, decay_mask="matrix",
                 ndim1_lr_scale=0.5)
    seg = build_segments(layout, spec)
    hps = leaf_hparams(spec, layout.shapes)
    # matrix-only decay: 1-d leaves get wd 0 and the ndim1 lr scale
    assert [hp.weight_decay for hp in hps] == [0.0, 0.0, 0.1, 0.1]
    assert [hp.lr_scale for hp in hps] == [0.5, 0.5, 1.0, 1.0]
    for b in range(layout.n_buckets):
        ids = seg.segment_ids(b)
        assert ids.shape == (layout.buf_sizes[b],)
        assert (ids[layout.sizes[b]:] == -1).all()      # tail sentinel
        for ordinal, (leaf, off) in enumerate(
            zip(layout.leaves[b], layout.offsets[b])
        ):
            n = int(np.prod(layout.shapes[leaf])) if layout.shapes[leaf] else 1
            assert (ids[off:off + n] == ordinal).all()
        sc, wd = seg.element_hparams(b)
        for ordinal, leaf in enumerate(layout.leaves[b]):
            span = ids == ordinal
            assert (sc[span] == hps[leaf].lr_scale).all()
            assert (wd[span] == np.float32(hps[leaf].weight_decay)).all()
        assert (sc[layout.sizes[b]:] == 0).all()
    # mixed-hparam buckets lose the uniform fast path
    assert seg.uniform(0) is None or seg.uniform(1) is None or all(
        hp == hps[0] for hp in hps
    )


def test_impl_dispatch_env_override(monkeypatch):
    """The REPRO_BUCKET_UPDATE env dispatch: valid overrides win over
    the backend default, unknown values raise instead of silently
    running the wrong implementation, empty falls back to the backend
    rule (ref on this CPU host)."""
    from repro.kernels.bucket_update.ops import default_bucket_update_impl

    def fresh(value):
        default_bucket_update_impl.cache_clear()
        if value is None:
            monkeypatch.delenv("REPRO_BUCKET_UPDATE", raising=False)
        else:
            monkeypatch.setenv("REPRO_BUCKET_UPDATE", value)
        try:
            return default_bucket_update_impl()
        finally:
            default_bucket_update_impl.cache_clear()

    assert fresh("interpret") == "interpret"
    assert fresh("REF") == "ref"                   # case-insensitive
    assert fresh(None) in ("pallas", "ref")
    with pytest.raises(ValueError, match="REPRO_BUCKET_UPDATE"):
        fresh("interpreted")                       # typo fails loudly


def test_uniform_fast_path_detection():
    params = _tree()
    layout = _layout(params)
    seg_u = build_segments(layout, adamw(1e-2, weight_decay=0.01))
    for b in range(layout.n_buckets):
        assert seg_u.uniform(b) == (1.0, 0.01)
    seg_n = build_segments(
        layout, adamw(1e-2, weight_decay=0.1, decay_mask="matrix")
    )
    # bucket 0 holds b(1d)+w(2d), bucket 1 holds h(1d)+u(3d): both mixed
    assert seg_n.uniform(0) is None and seg_n.uniform(1) is None


# ---------------------------------------------------------------------------
# Flat runtime: delayed-update staleness (k>1) and donation/no-growth
# ---------------------------------------------------------------------------
def _live_bytes():
    return sum(
        a.nbytes for a in jax.live_arrays() if not a.is_deleted()
    )


def test_flat_runtime_staleness_and_no_buffer_growth(single_mesh):
    """cr=1.8 gives a delayed-update schedule (k>1 merged gradients,
    updates applied phases after their batches).  The flat engine must
    (a) track the gradient-accumulation reference through the stale
    applies and (b) hold the donation contract: the live-buffer footprint
    does not grow across a full period."""
    from repro.configs import get_config, reduce_for_smoke
    from test_train_steps import B, S, _ReferenceReplay, _schedule_for
    from repro.train import DeftRuntime, init_train_state

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=1.8)
    assert max(sched.batch_size_sequence) > 1          # real staleness
    assert sched.updates_per_period < sched.period
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    ref = _ReferenceReplay(cfg, opt, probe["params"])
    del probe

    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        assert rt.flat_state
        state = rt.init_state(key)
        rt.compile(state, make_batch(cfg, 0, 0, B, S))
        baseline = None
        for step in range(2 * sched.period):
            batch = make_batch(cfg, 0, step, B, S)
            prev = state
            state, m = rt.step(step, state, batch)
            assert all(x.is_deleted() for x in jax.tree.leaves(prev)), (
                f"step {step}: donation did not hold"
            )
            ref.step(sched.phases[step % sched.period], batch)
            diff = ref.max_param_diff(rt.params_tree(state))
            assert diff < 5e-5, f"step {step}: diverged by {diff}"
            jax.block_until_ready(m["loss"])
            if step == sched.period - 1:
                baseline = _live_bytes()
        assert baseline is not None
        # steady state: repeating the cycle allocates nothing persistent
        assert _live_bytes() <= baseline, (
            f"live buffers grew across a period: "
            f"{baseline} -> {_live_bytes()}"
        )


def _count_eqns(jaxpr):
    n = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for p in eqn.params.values():
            for sub in _subjaxprs(p):
                n += _count_eqns(sub)
    return n


def _subjaxprs(p):
    core = jax.core
    if isinstance(p, core.ClosedJaxpr):
        return [p.jaxpr]
    if isinstance(p, core.Jaxpr):
        return [p]
    if isinstance(p, (list, tuple)):
        return [j for x in p for j in _subjaxprs(x)]
    return []


def test_flat_update_removes_per_leaf_op_sequence():
    """THE structural claim of the flat engine, asserted the same way
    the runtime asserts its collectives guarantee — by jaxpr
    inspection, which is deterministic where CPU wall time is not: the
    fused apply's op count scales with the bucket count, the per-leaf
    apply's with the leaf count."""
    n_leaves, leaf_elems, n_buckets = 64, 512, 4
    key = jax.random.PRNGKey(5)
    tree = {
        f"l{i:03d}": jax.random.normal(jax.random.fold_in(key, i),
                                       (leaf_elems,))
        for i in range(n_leaves)
    }
    grads = jax.tree.map(lambda p: p * 0.01, tree)
    bo = tuple(i * n_buckets // n_leaves for i in range(n_leaves))
    layout = build_bucket_layout(tree, bo, n_buckets)
    spec = adamw(1e-3)
    seg = build_segments(layout, spec)
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(tree)))
    gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    opt_l = init_opt_state(spec, tree)

    n_flat = _count_eqns(jax.make_jaxpr(
        lambda p, g, o: apply_bucket_updates(spec, seg, p, g, o,
                                             grad_scale=0.1)[:2]
    )(pbuf, gbuf, opt_f).jaxpr)
    n_leaf = _count_eqns(jax.make_jaxpr(
        lambda p, g, o: apply_updates(spec, p, g, o, grad_scale=0.1)
    )(tree, grads, opt_l).jaxpr)
    # per-leaf grows ~10 ops/leaf; fused grows ~10 ops/bucket
    assert n_flat < n_leaf / 4, (n_flat, n_leaf)
    assert n_leaf > n_leaves            # really is O(leaves)


def test_bench_update_path_entry():
    """The checked-in BENCH_runtime.json update-path entry exists, is
    structurally sound, and shows no gross update-path regression at
    paper-regime leaf counts.  Wall-clock on a shared CPU is load-noisy
    (observed 1.0x-8.8x across runs), so the hard perf claim lives in
    test_flat_update_removes_per_leaf_op_sequence; this floor only
    catches the engine becoming categorically slower."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_runtime.json")
    data = json.load(open(path))
    up = data["update_path"]
    assert up["paper_leafcount"]["speedup_flat_vs_per_leaf"] > 0.9, up
    assert up["paper_leafcount"]["n_leaves"] >= 100
    assert up["smoke_config"]["apply_ms_flat"] > 0
    # the sharded engine's ISOLATED floor — same contract on the RS
    # path: the whole-phase scenario numbers (even interleaved) stay CPU
    # load-noisy, so the gate reads only this signal
    us = data["fsdp_flat"]["update_path_sharded"]
    assert us["speedup_flat_vs_per_leaf"] > 0.9, us
    assert us["n_leaves"] >= 100 and us["shard_count"] > 1


def test_flat_runtime_checkpoint_roundtrip(single_mesh):
    """state_to_tree / tree_to_state are exact inverses and params_tree
    matches the legacy tree layout leaf-for-leaf."""
    from repro.configs import get_config, reduce_for_smoke
    from test_train_steps import B, S, _schedule_for
    from repro.train import DeftRuntime, init_train_state

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(3)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=0.5)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        state = rt.init_state(key)
        state, _ = rt.step(0, state, make_batch(cfg, 0, 0, B, S))
        tree = rt.state_to_tree(state)
        assert set(tree) == {"params", "opt", "cur", "fut"}
        for a, b in zip(jax.tree.leaves(tree["params"]),
                        jax.tree.leaves(rt.params_tree(state))):
            assert bool(jnp.array_equal(a, b))
        back = rt.tree_to_state(tree)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
            assert bool(jnp.array_equal(a, b)), "roundtrip not exact"
