"""Property-testing shim: real hypothesis when installed, else a tiny
deterministic fallback.

The fallback implements exactly the strategy subset the repo's property
tests use — ``integers``, ``floats``, ``booleans``, ``lists``, ``tuples``
and ``.flatmap``/``.map`` — and a ``given``/``settings`` pair that draws
``max_examples`` pseudo-random examples from a seed derived from the test
name (stable across runs, so failures reproduce).  It trades hypothesis'
shrinking and edge-case heuristics for zero dependencies; with hypothesis
installed the real library is used untouched.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on the CI container
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

        def flatmap(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)).example(rng))

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_ignored):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 10

            def draw(rng):
                n = rng.randint(min_size, hi)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strategies)
            )

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    st = _Strategies()

    def settings(max_examples=50, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 25)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = [s.example(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # hide the wrapped signature from pytest: the strategy-filled
            # parameters must not be collected as fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
