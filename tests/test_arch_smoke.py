"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family (<=3 layers, d_model=256, <=4 experts) runs one
forward + one train step on CPU with correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, reduce_for_smoke
from repro.data.pipeline import make_batch
from repro.models.model import init_params, loss_fn
from repro.optim.optimizers import adamw, apply_updates, init_opt_state

B, S = 2, 32


def _batch(cfg, key):
    b = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.modality != "text":
        b["memory"] = jax.random.normal(
            key, (B, max(cfg.n_modal_tokens, 1), cfg.d_model)
        )
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)

    (loss, parts), grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: loss_fn(q, cfg, b), has_aux=True
        )(p)
    )(params, batch)

    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0

    # one optimizer step moves the params and keeps them finite
    opt = adamw(1e-3)
    state = init_opt_state(opt, params)
    new_params, _ = apply_updates(opt, params, grads, state)
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert moved
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_synthetic_batch_compatible(arch):
    cfg = reduce_for_smoke(get_config(arch))
    b = make_batch(cfg, seed=0, step=0, batch=B, seq_len=S)
    assert b["tokens"].shape == (B, S)
    assert int(jnp.max(b["tokens"])) < cfg.vocab_size
    if cfg.modality != "text":
        assert b["memory"].shape == (B, cfg.n_modal_tokens, cfg.d_model)
