"""Precision as a layout dimension (DESIGN.md §13).

Covers, tier-1:

* the quantize kernel twins: Pallas-under-interpret vs the pure-JAX ref
  BIT-MATCH (int8 values, scales, dequant; stochastic-rounded bf16),
  including hostile NaN/inf padded tails and multi-program grids;
* int8 blockwise error bound (elementwise |x - dq| <= scale/2) and
  deterministic, seed-sensitive, unbiased stochastic rounding;
* the ONE cast site: kernels/quantize.cast_compute is bitwise-identical
  to the legacy inline casts it replaced (the PR-4 asymmetry fix);
* the precision-aware Preserver gate: a noise-sensitive walk rejects an
  int8 wire that a clean walk would accept, and the gate is one-sided;
* the planner ladder: under a bandwidth-constrained profile the chosen
  mixed per-bucket policy STRICTLY increases simulated coverage over
  all-f32;
* end-to-end: a forced-int8-wire bucket trains within a tight bound of
  the f32 reference while measurably quantizing; a bf16sr resident
  master stays within the expected drift envelope; a precision-only
  hot-swap installs at the cycle boundary with zero restart.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bucket import BucketTimes
from repro.core.deft import Planner, PlanRequest
from repro.core.precision import (
    WIRE_BYTES,
    PrecisionPolicy,
    apply_wire_precision,
    check_precision_schedule,
    wire_bytes_total,
)
from repro.core.preserver import WalkParams, check_schedule
from repro.kernels.quantize import (
    cast_compute,
    dequantize_int8,
    quantize_int8,
    stochastic_round_bf16,
)
from repro.kernels.quantize.ref import quantize_int8_ref

SHAPES = (128, 512, 1280, 4096)


def _buf(n, key=0, scale=3.0):
    x = jax.random.normal(jax.random.PRNGKey(key), (n,)) * scale
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# kernel twins bit-match (pallas-interpret vs ref)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", SHAPES)
def test_int8_interpret_matches_ref_bitwise(n):
    x = _buf(n, key=n)
    q1, s1 = quantize_int8(x, impl="interpret")
    q2, s2 = quantize_int8(x, impl="ref")
    assert q1.dtype == jnp.int8 and s1.dtype == jnp.float32
    assert bool(jnp.array_equal(q1, q2))
    assert bool(jnp.array_equal(
        jax.lax.bitcast_convert_type(s1, jnp.uint32),
        jax.lax.bitcast_convert_type(s2, jnp.uint32),
    ))
    d1 = dequantize_int8(q1, s1, impl="interpret")
    d2 = dequantize_int8(q2, s2, impl="ref")
    assert bool(jnp.array_equal(d1, d2))


@pytest.mark.parametrize("n", SHAPES)
def test_sr_bf16_interpret_matches_ref_bitwise(n):
    x = _buf(n, key=n + 1)
    a = stochastic_round_bf16(x, 7, impl="interpret")
    b = stochastic_round_bf16(x, 7, impl="ref")
    assert a.dtype == jnp.bfloat16
    assert bool(jnp.array_equal(a, b))


def test_sr_bf16_multi_program_grid_matches_ref():
    """The in-kernel global flat index (program_id * block * 128 + iota)
    must make the hash independent of the grid geometry."""
    from repro.kernels.quantize.kernel import stochastic_round_bf16_pallas
    from repro.kernels.quantize.ref import stochastic_round_bf16_ref

    x = _buf(1280, key=3)
    for br in (1, 2, 4, 10):
        a = stochastic_round_bf16_pallas(x, 5, block_rows=br, interpret=True)
        assert bool(jnp.array_equal(a, stochastic_round_bf16_ref(x, 5)))


def test_hostile_padded_tails_zeroed():
    """NaN/inf beyond n_valid must never leak through a wire cast."""
    n, valid = 512, 300
    x = _buf(n).at[valid:].set(jnp.nan).at[valid + 3].set(jnp.inf)
    for impl in ("interpret", "ref"):
        y = stochastic_round_bf16(x, 1, valid, impl=impl)
        assert bool(jnp.all(y[valid:] == 0))
        assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
        q, s = quantize_int8(x, valid, impl=impl)
        d = dequantize_int8(q, s, valid, impl=impl)
        assert bool(jnp.all(d[valid:] == 0))
        assert bool(jnp.all(jnp.isfinite(d)))


# ---------------------------------------------------------------------------
# numeric properties
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bound():
    """Blockwise quantization error is elementwise <= scale/2."""
    for key in range(3):
        x = _buf(2048, key=key, scale=10.0 ** (key - 1))
        q, s = quantize_int8(x, impl="ref")
        d = dequantize_int8(q, s, impl="ref")
        err = jnp.abs(d - x).reshape(-1, 128)
        bound = (s * 0.5)[:, None] + 1e-12
        assert bool(jnp.all(err <= bound))


def test_int8_zero_row_scale_is_one():
    x = jnp.zeros((256,), jnp.float32)
    q, s = quantize_int8(x, impl="ref")
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s == 1.0))


def test_sr_bf16_deterministic_and_seed_sensitive():
    x = _buf(1024, key=9)
    a = stochastic_round_bf16(x, 42, impl="ref")
    b = stochastic_round_bf16(x, 42, impl="ref")
    c = stochastic_round_bf16(x, 43, impl="ref")
    assert bool(jnp.array_equal(a, b))
    assert not bool(jnp.array_equal(a, c))


def test_sr_bf16_unbiased():
    """E[round(x)] == x: a value exactly between two bf16 neighbours
    must round up about half the time across seeds."""
    hi = jnp.float32(1.0 + 2.0 ** -7)        # next bf16 after 1.0
    x = jnp.full((128,), 1.0 + 2.0 ** -8, jnp.float32)   # the midpoint
    ups = []
    for seed in range(64):
        y = stochastic_round_bf16(x, seed, impl="ref").astype(jnp.float32)
        ups.append(float(jnp.mean((y == hi).astype(jnp.float32))))
    frac = np.mean(ups)
    assert 0.4 < frac < 0.6, frac


def test_cast_compute_matches_legacy_inline_casts():
    """The unified cast site must be bit-identical to the legacy inline
    ``astype`` casts it replaced (replicated buffer views AND sharded
    pre-gather), in both directions."""
    x = _buf(777, key=2)
    assert cast_compute(x, None) is x
    assert cast_compute(x, jnp.float32) is x
    down = cast_compute(x, jnp.bfloat16)
    assert bool(jnp.array_equal(down, x.astype(jnp.bfloat16)))
    up = cast_compute(down, jnp.float32)
    assert bool(jnp.array_equal(up, down.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# policy / pricing / gate
# ---------------------------------------------------------------------------
def test_policy_validation_and_bytes():
    p = PrecisionPolicy(wire=("f32", "bf16", "int8"))
    assert [p.wire_bytes_per_elem(b) for b in range(3)] == [4, 2, 1]
    assert p.mixed and not p.all_f32
    assert "bf16" in p.describe()
    with pytest.raises(ValueError):
        PrecisionPolicy(wire=("fp8",))
    with pytest.raises(ValueError):
        PrecisionPolicy(wire=("f32",), master="f16")
    assert wire_bytes_total((100, 100, 100), p) \
        == 100 * (WIRE_BYTES["f32"] + WIRE_BYTES["bf16"] + WIRE_BYTES["int8"])


def test_apply_wire_precision_prices_bandwidth_term_only():
    times = BucketTimes(fwd=(1e-3,) * 2, bwd=(1e-3,) * 2,
                        comm=(10e-3, 20e-3))
    p = PrecisionPolicy(wire=("bf16", "int8"))
    out = apply_wire_precision(times, p)
    lat = 20e-6
    assert out.comm[0] == pytest.approx(lat + (10e-3 - lat) * 0.5)
    assert out.comm[1] == pytest.approx(lat + (20e-3 - lat) * 0.25)
    assert out.fwd == times.fwd and out.bwd == times.bwd


def test_precision_gate_one_sided_and_noise_sensitive():
    """Near the noise floor (s0 ~ s_star) the sigma-inflated O_D walk
    must reject int8 while the clean gate accepts the same schedule."""
    walk = WalkParams(s0=1.02, s_star=1.0, eta=0.05, mu=0.9,
                      sigma=2.0, batch=32)
    ks = (1, 1, 1, 1)
    clean = check_schedule(ks, 4, walk, eps=0.02)
    assert clean.ok
    f32 = check_precision_schedule(
        ks, 4, walk, PrecisionPolicy.uniform(2, "f32"), eps=0.02
    )
    assert f32.ok and f32.ratio == pytest.approx(clean.ratio)
    int8 = check_precision_schedule(
        ks, 4, walk, PrecisionPolicy.uniform(2, "int8"), eps=0.02
    )
    assert not int8.ok
    # one-sided: narrowing the wire inflates only O_D's noise, so the
    # ratio e_B/e_D can only fall — quantization never rescues a
    # failing k-sequence
    bf16 = check_precision_schedule(
        ks, 4, walk, PrecisionPolicy.uniform(2, "bf16"), eps=0.02
    )
    assert f32.ratio >= bf16.ratio >= int8.ratio


def _constrained_times(n=8):
    rng = np.random.default_rng(0)
    comm = tuple(float(c) for c in rng.uniform(0.04, 0.09, n))
    return BucketTimes(fwd=(0.004,) * n, bwd=(0.008,) * n, comm=comm)


def test_planner_mixed_precision_increases_coverage():
    """Acceptance criterion: under a bandwidth-constrained profile the
    auto ladder picks a MIXED per-bucket policy whose simulated coverage
    strictly beats all-f32."""
    req = PlanRequest(times=_constrained_times(), wire_precision="auto",
                      sim_iterations=3)
    res = Planner().plan(req)
    assert res.precision is not None
    base = next(
        c for c in res.precision_candidates if c.policy.all_f32
    )
    best = next(
        c for c in res.precision_candidates if c.policy == res.precision
    )
    assert best.coverage > base.coverage
    assert best.iteration_time < base.iteration_time
    assert best.wire_bytes_scale < 1.0
    assert res.priced_times is not None
    assert sum(res.priced_times.comm) < sum(res.times.comm)


def test_planner_forced_uniform_and_explicit_policy():
    times = _constrained_times(4)
    res = Planner().plan(PlanRequest(times=times, wire_precision="bf16",
                                     sim_iterations=4))
    assert res.precision is not None
    assert set(res.precision.wire) <= {"f32", "bf16"}
    pol = PrecisionPolicy(wire=("int8", "f32", "f32", "f32"))
    res2 = Planner().plan(PlanRequest(times=times, precision=pol,
                                      sim_iterations=4))
    assert res2.precision in (pol, PrecisionPolicy.uniform(4, "f32"))


# ---------------------------------------------------------------------------
# adaptive controller: bandwidth collapse unlocks the precision ladder
# ---------------------------------------------------------------------------
def test_controller_bandwidth_collapse_downgrades_wire():
    """A calibrated comm_scale past ``precision_comm_scale`` escalates
    the replan to wire_precision='auto': the controller downgrades the
    wire instead of surrendering coverage to the starved link, and the
    ReplanEvent carries the adopted policy + bytes delta."""
    from repro.adapt import (
        AdaptConfig,
        AdaptiveController,
        BandwidthDrop,
        SyntheticTelemetrySource,
        run_control_loop,
    )
    from repro.core.preserver import WalkParams as WP

    walk = WP(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    times = _constrained_times(8)
    res0 = Planner().plan(PlanRequest(times=times, walk=walk))
    # escalation bar at the drift threshold: the first replan fires
    # while the EMA is still settling toward the injected 3x, so its
    # fitted comm_scale undershoots the asymptote
    cfg = AdaptConfig(wire_precision="auto", precision_comm_scale=1.25)
    ctrl = AdaptiveController(
        times, res0.schedule, res0.scheduler_cfg, walk=walk, cfg=cfg
    )
    assert ctrl.precision is None
    drop = BandwidthDrop(step=24, comm_scale=3.0)
    events = run_control_loop(
        ctrl, SyntheticTelemetrySource(times, drop), 96
    )
    assert events, "no replan despite a 3x bandwidth collapse"
    e = events[0]
    assert e.profile.comm_scale >= cfg.precision_comm_scale
    assert e.new_precision is not None
    assert not e.new_precision.all_f32, "wire stayed f32 under collapse"
    assert e.precision_changed and e.changed
    assert e.wire_bytes_scale < 1.0
    assert "PRECISION" in e.describe()
    # controller state tracks the latest adopted policy (the synthetic
    # source never actually quantizes its reported wall times, so later
    # replans may legitimately revise the first event's choice)
    assert ctrl.precision == events[-1].new_precision
    assert ctrl.stats()["precision_changes"] >= 1
    assert ctrl.stats()["wire_precision"] == (
        ctrl.precision.describe() if ctrl.precision else "f32"
    )


# ---------------------------------------------------------------------------
# end-to-end (runtime execution of a policy)
# ---------------------------------------------------------------------------
def _smoke_runtime(layout_precision=None, master_dtype=None, seed=0):
    from repro.configs import get_config, reduce_for_smoke
    from repro.optim.optimizers import adamw
    from repro.train import DeftRuntime, init_train_state
    from repro.train.bucketing import build_bucket_layout
    from test_train_steps import _schedule_for

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(seed)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=0.5)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    if layout_precision is not None:
        layout = layout.with_precision(layout_precision)
    from repro.train.runtime import RuntimeConfig
    rt_cfg = RuntimeConfig(master_dtype=master_dtype)
    return cfg, opt, sched, layout, key, rt_cfg


def _run_steps(rt, state, cfg, sched, n):
    from repro.data.pipeline import make_batch
    from test_train_steps import B, S

    losses = []
    for step in range(n):
        batch = make_batch(cfg, 0, step, B, S)
        state, m = rt.step(step, state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def test_runtime_int8_wire_bucket_close_to_f32(single_mesh):
    """A forced-int8 wire bucket executes through the quantize edge and
    stays within a tight bound of the f32 reference trajectory."""
    from repro.train import DeftRuntime

    cfg, opt, sched, layout, key, _ = _smoke_runtime()
    nb = layout.n_buckets
    pol = PrecisionPolicy(wire=("int8",) + ("f32",) * (nb - 1))
    lay_q = layout.with_precision(pol)
    with single_mesh:
        rt_f = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        rt_q = DeftRuntime(cfg, opt, sched, lay_q, single_mesh)
        assert rt_q.stats()["wire_precision"] == pol.describe()
        s_f = rt_f.init_state(key)
        s_q = rt_q.init_state(key)
        n = sched.period * 2
        s_f, l_f = _run_steps(rt_f, s_f, cfg, sched, n)
        s_q, l_q = _run_steps(rt_q, s_q, cfg, sched, n)
    assert np.all(np.isfinite(l_q))
    diff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32))))
        for a, b in zip(s_f["pbuf"], s_q["pbuf"])
    )
    # quantization must actually bite (the edge is live) ...
    assert diff > 0.0
    # ... but the trajectory stays within a tight envelope of f32
    assert diff < 5e-3, diff
    assert abs(l_f[-1] - l_q[-1]) < 0.05


def test_runtime_bf16sr_master_bounded_drift(single_mesh):
    """The bf16sr resident master: params live at bf16, updates write
    back through seeded stochastic rounding, and the trajectory stays
    within the expected rounding envelope of the f32 master run."""
    from repro.train import DeftRuntime

    cfg, opt, sched, layout, key, rt_cfg = _smoke_runtime(
        master_dtype="bf16sr"
    )
    with single_mesh:
        rt_f = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        rt_b = DeftRuntime(cfg, opt, sched, layout, single_mesh,
                           config=rt_cfg)
        assert rt_b.stats()["master_dtype"] == "bf16sr"
        s_f = rt_f.init_state(key)
        s_b = rt_b.init_state(key)
        for p in s_b["pbuf"]:
            assert p.dtype == jnp.bfloat16
        n = sched.period * 2
        s_f, l_f = _run_steps(rt_f, s_f, cfg, sched, n)
        s_b, l_b = _run_steps(rt_b, s_b, cfg, sched, n)
        # determinism: the seeded rounding reproduces exactly
        s_b2 = rt_b.init_state(key)
        s_b2, _ = _run_steps(rt_b, s_b2, cfg, sched, n)
    assert np.all(np.isfinite(l_b))
    for a, b in zip(s_b["pbuf"], s_b2["pbuf"]):
        assert bool(jnp.array_equal(a, b))
    rel = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b))
              / (jnp.max(jnp.abs(b)) + 1e-9))
        for a, b in zip(s_b["pbuf"], s_f["pbuf"])
    )
    assert rel < 0.05, rel


def test_precision_hot_swap_at_cycle_boundary(single_mesh):
    """A mid-run wire-precision change is a cycle-boundary layout swap:
    no restart, the repack is pure aliasing (zero moved elements), and
    the new policy is live from the boundary on."""
    from repro.train import DeftRuntime

    cfg, opt, sched, layout, key, _ = _smoke_runtime()
    nb = layout.n_buckets
    lay_q = layout.with_precision(PrecisionPolicy.uniform(nb, "bf16"))
    assert lay_q != layout
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        state = rt.init_state(key)
        state, _ = _run_steps(rt, state, cfg, sched, sched.period)
        from repro.data.pipeline import make_batch
        from test_train_steps import B, S

        batch = make_batch(cfg, 0, 0, B, S)
        info = rt.prepare_swap(sched, state, batch, layout=lay_q)
        assert info["layout_change"] and info["moved_elems"] == 0
        assert rt.swap_ready()
        for step in range(sched.period, 2 * sched.period):
            batch = make_batch(cfg, 0, step, B, S)
            state, m = rt.step(step, state, batch)
            assert bool(jnp.isfinite(m["loss"]))
        assert rt.hot_swaps == 1 and rt.layout_swaps == 1
        assert rt.layout is lay_q
        assert rt.stats()["wire_precision"] == "bf16x" + str(nb)


def test_runtime_wire_bytes_match_plan(single_mesh):
    """The bytes the executed collectives ship (collective-group span
    attrs) must equal what the knapsack priced — the §13 acceptance
    loop: policy -> pricing -> execution -> measured attribution."""
    from repro.obs import Tracer, wire_bytes_report
    from repro.train import DeftRuntime

    cfg, opt, sched, layout, key, _ = _smoke_runtime()
    nb = layout.n_buckets
    pol = PrecisionPolicy(
        wire=("int8", "bf16") + ("f32",) * (nb - 2)
    )
    lay_q = layout.with_precision(pol)
    tracer = Tracer(capacity=1 << 14)
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, lay_q, single_mesh,
                         tracer=tracer)
        state = rt.init_state(key)
        state, _ = _run_steps(rt, state, cfg, sched, sched.period * 2)
    planned = rt.wire_bytes_per_phase
    assert len(planned) == sched.period
    rep = wire_bytes_report(tracer, planned)
    assert rep.planned_per_cycle == sum(planned)
    assert rep.ok, (rep.planned_per_phase, rep.measured_per_phase)
    observed = [p for p in rep.precisions if p is not None]
    assert observed and all(p == pol.describe() for p in observed)
    assert rt.stats()["planned_wire_bytes_per_cycle"] == sum(planned)


def test_runtime_rejects_master_dtype_changing_swap(single_mesh):
    """Hot-swaps may change wire precision but never the resident
    master dtype — that would need a state-wide cast, not a repack."""
    from repro.train import DeftRuntime

    cfg, opt, sched, layout, key, _ = _smoke_runtime()
    nb = layout.n_buckets
    bad = layout.with_precision(
        PrecisionPolicy.uniform(nb, "f32", master="bf16sr")
    )
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        state = rt.init_state(key)
        from repro.data.pipeline import make_batch
        from test_train_steps import B, S

        batch = make_batch(cfg, 0, 0, B, S)
        with pytest.raises(ValueError, match="master"):
            rt.prepare_swap(sched, state, batch, layout=bad)


# ---------------------------------------------------------------------------
# checkpoint sidecar: the policy is part of the layout a resume rebuilds
# ---------------------------------------------------------------------------
def test_layout_descriptor_roundtrips_precision(tmp_path):
    """save_layout_descriptor records the §13 wire/master policy and
    load_layout_descriptor rebuilds the SAME quantized layout — a
    resume under a bf16sr master must not silently come back f32."""
    from repro.checkpoint.checkpoint import (
        load_layout_descriptor,
        save_layout_descriptor,
    )
    from repro.train.bucketing import build_bucket_layout

    params = {f"l{i}": jnp.zeros((64,), jnp.float32) for i in range(4)}
    bucket_of, nb = (0, 0, 1, 2), 3
    pol = PrecisionPolicy(wire=("int8", "bf16", "f32"), master="bf16sr")
    lay = build_bucket_layout(params, bucket_of, nb, precision=pol)
    save_layout_descriptor(str(tmp_path), 7, lay, next_phase=1,
                           digest="d")
    got, phase, digest = load_layout_descriptor(str(tmp_path), 7, params)
    assert (phase, digest) == (1, "d")
    assert got.precision == pol
    assert got.bucket_of_leaf == lay.bucket_of_leaf

    # a policy-free layout stays policy-free on reload
    lay0 = build_bucket_layout(params, bucket_of, nb)
    save_layout_descriptor(str(tmp_path), 8, lay0)
    got0, _, _ = load_layout_descriptor(str(tmp_path), 8, params)
    assert got0.precision is None
