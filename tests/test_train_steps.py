"""Compiled DeFT phase steps: equivalence with an explicit gradient-
accumulation reference that replays the PhaseSpec semantics with global
gradients.  This is the convergence-consistency evidence the paper gets
from its ImageNet runs — here it is exact (to f32 reduction order).

Runs on a 1x1 mesh — the full shard_map/psum graph is built; a true
multi-device run of the same check lives in test_multidevice.py.
"""
import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import solve_schedule
from repro.core.scheduler import SchedulerConfig
from repro.data.pipeline import make_batch
from repro.models.model import loss_fn
from repro.optim.optimizers import adamw, apply_updates, init_opt_state
from repro.train import (
    DeftRuntime,
    assign_buckets,
    build_bucket_layout,
    init_train_state,
    leaf_bucket_times,
    make_deft_step_fns,
    phase_collectives,
)
from repro.train.runtime import deft_phase_step_fused
from repro.train.steps import ddp_train_step, deft_phase_step
from repro.core.profiler import HardwareModel

B, S = 4, 32


def _schedule_for(cfg, params, cr, heterogeneous=True):
    bucket_of, nb = assign_buckets(params, cfg, partition_elems=150_000)
    hw = HardwareModel(dp_degree=1)
    times = leaf_bucket_times(params, cfg, bucket_of, nb, hw, S, B)
    scale = cr * (times.fwd_total + times.bwd_total) / max(times.comm_total, 1e-12)
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    return bucket_of, nb, solve_schedule(
        times, SchedulerConfig(heterogeneous=heterogeneous)
    )


class _ReferenceReplay:
    """Replays PhaseSpec semantics with global (unbucketed) gradients —
    the gradient-accumulation reference both step implementations must
    match exactly (to f32 reduction order)."""

    def __init__(self, cfg, opt, params):
        self.cfg, self.opt = cfg, opt
        self.params = params
        self.opt_state = init_opt_state(opt, params)
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        self.cur, self.fut = zeros(), zeros()
        self.gfn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))

    def step(self, ph, batch):
        g = self.gfn(self.params, batch)
        if ph.rotate:
            gen = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) + b, g, self.fut
            )
            self.fut = jax.tree.map(jnp.zeros_like, self.fut)
        else:
            self.fut = jax.tree.map(
                lambda f, a: f + a.astype(jnp.float32), self.fut, g
            )
            gen = None
        if ph.do_update:
            src = self.cur if ph.update_source == "cur" else gen
            self.params, self.opt_state = apply_updates(
                self.opt, self.params, src, self.opt_state,
                grad_scale=1.0 / ph.update_k,
            )
            self.cur = gen if ph.update_source == "cur" else \
                jax.tree.map(jnp.zeros_like, self.cur)
        elif ph.rotate:
            self.cur = gen

    def max_param_diff(self, params) -> float:
        return max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(self.params))
        )


@pytest.mark.parametrize("cr", [0.5, 1.8])
def test_deft_steps_match_accumulation_reference(single_mesh, cr):
    """Legacy per-leaf path vs the reference replay."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    state = init_train_state(key, cfg, opt, deft=True, accum_devices=1)
    bucket_of, _, sched = _schedule_for(cfg, state["params"], cr)
    if cr > 1:
        assert sched.updates_per_period < sched.period

    ref = _ReferenceReplay(cfg, opt, state["params"])
    with single_mesh:
        fns = make_deft_step_fns(cfg, opt, sched, bucket_of, single_mesh)
        for step in range(2 * sched.period):
            batch = make_batch(cfg, 0, step, B, S)
            ph = sched.phases[step % sched.period]
            state, m = fns[step % sched.period](state, batch)
            ref.step(ph, batch)
            diff = ref.max_param_diff(state["params"])
            assert diff < 5e-5, f"step {step}: params diverge by {diff}"
            assert bool(m["updated"]) == ph.do_update


@pytest.mark.parametrize("flat_state", [True, False],
                         ids=["flat", "tree"])
@pytest.mark.parametrize("cr", [0.5, 1.8])
def test_fused_runtime_matches_accumulation_reference(single_mesh, cr,
                                                      flat_state):
    """DeftRuntime (bucket-fused collectives, donated buffers, AOT phase
    cache) vs the same gradient-accumulation reference — both the flat-
    resident engine (fused bucket-update path; cr=1.8 exercises delayed
    k>1 stale-gradient updates) and the PR-1 tree-state engine."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)

    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh,
                         flat_state=flat_state)
        state = rt.init_state(key)
        rt.compile(state, make_batch(cfg, 0, 0, B, S))   # AOT phase cache
        ref = _ReferenceReplay(cfg, opt, probe["params"])
        for step in range(2 * sched.period):
            batch = make_batch(cfg, 0, step, B, S)
            ph = sched.phases[step % sched.period]
            state, m = rt.step(step, state, batch)
            ref.step(ph, batch)
            diff = ref.max_param_diff(rt.params_tree(state))
            assert diff < 5e-5, f"step {step}: params diverge by {diff}"
            assert bool(m["updated"]) == ph.do_update
    st = rt.stats()
    assert st["steps_dispatched"] == 2 * sched.period
    assert st["unique_phases"] <= sched.period
    assert st["compile_s_total"] > 0.0
    assert st["flat_state"] == flat_state


# ---------------------------------------------------------------------------
# Fused-path structural guarantees
# ---------------------------------------------------------------------------
_COLLECTIVE_PRIMS = {
    "psum", "psum_scatter", "reduce_scatter", "all_gather", "all_reduce",
    "all_to_all",
}


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            for sub in _subjaxprs(p):
                yield from _iter_eqns(sub)


def _subjaxprs(p):
    core = jax.core
    if isinstance(p, core.ClosedJaxpr):
        return [p.jaxpr]
    if isinstance(p, core.Jaxpr):
        return [p]
    if isinstance(p, (list, tuple)):
        return [j for x in p for j in _subjaxprs(x)]
    return []


def _count_collectives(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(
        1 for eqn in _iter_eqns(jaxpr.jaxpr)
        if eqn.primitive.name in _COLLECTIVE_PRIMS
    )


def test_fused_phase_one_collective_per_synced_bucket(single_mesh):
    """THE fusion guarantee: the fused phase body contains exactly one
    psum per synced bucket (+1 fused metrics psum), while the legacy body
    holds one per synced parameter leaf (+3 metric psums).  Asserted by
    jaxpr inspection, homogeneous link setup (no secondary chains)."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(
        cfg, probe["params"], cr=1.8, heterogeneous=False
    )
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    batch = make_batch(cfg, 0, 0, B, S)
    legacy_state = init_train_state(key, cfg, opt, deft=True, accum_devices=1)
    fused_state = init_train_state(
        key, cfg, opt, deft=True, accum_devices=1, layout=layout
    )

    checked = 0
    with single_mesh:
        for ph in set(sched.phases):
            synced = [
                (ph.route_new[b] == "sync" and ph.rotate) or ph.sync_cur[b]
                for b in range(nb)
            ]
            n_synced_buckets = sum(synced)
            n_synced_leaves = sum(
                len(layout.leaves[b]) for b in range(nb) if synced[b]
            )
            assert not any(ph.secondary), "homogeneous schedule expected"

            fused = _count_collectives(
                functools.partial(
                    deft_phase_step_fused, cfg=cfg, opt_spec=opt, phase=ph,
                    layout=layout, mesh=single_mesh,
                ),
                fused_state, batch,
            )
            legacy = _count_collectives(
                functools.partial(
                    deft_phase_step, cfg=cfg, opt_spec=opt, phase=ph,
                    bucket_of_leaf=bucket_of, mesh=single_mesh,
                ),
                legacy_state, batch,
            )
            expected = phase_collectives(ph)
            assert expected["primary"] == n_synced_buckets
            assert fused == n_synced_buckets + 1, (fused, n_synced_buckets)
            assert legacy == n_synced_leaves + 3, (legacy, n_synced_leaves)
            if n_synced_buckets:
                checked += 1
                assert fused < legacy  # the actual win
    assert checked > 0   # at least one phase actually syncs something


def test_fused_runtime_donation_holds(single_mesh):
    """Every phase executable donates the whole train state: after a
    dispatch the input buffers are deleted (updated in place), across a
    full multi-phase period without aliasing errors."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=1.8)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        state = rt.init_state(key)
        batch = make_batch(cfg, 0, 0, B, S)
        rt.compile(state, batch)
        for step in range(sched.period):
            prev = state
            state, m = rt.step(step, state, batch)
            leaves = jax.tree.leaves(prev)
            assert leaves and all(x.is_deleted() for x in leaves), (
                f"step {step}: donation did not hold"
            )
        assert jnp.isfinite(m["loss"])


def test_low_cr_full_update_frequency_and_progress(single_mesh):
    """CR << 1: the schedule keeps the baseline update frequency (one
    k=1 update per iteration; only the hard-dependency bucket rides into
    the next iteration's forward — the paper's delayed update) and the
    loss actually descends on the learnable stream."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(1)
    state = init_train_state(key, cfg, opt, deft=True, accum_devices=1)
    bucket_of, nb, sched = _schedule_for(cfg, state["params"], cr=0.05)
    assert sched.updates_per_period == sched.period  # one update per iter
    assert all(k == 1 for k in sched.batch_size_sequence)

    losses = []
    layout = build_bucket_layout(state["params"], bucket_of, nb)
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        state = rt.init_state(key)
        for step in range(10):
            batch = make_batch(cfg, 0, step, B, S)
            state, m = rt.step(step, state, batch)
            assert bool(m["updated"])
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_loss_chunk_matches_unchunked(single_mesh):
    """Chunked LM-head CE == plain CE (same loss, same gradients)."""
    cfg = reduce_for_smoke(get_config("gemma2-2b"))   # softcaps + tied embed
    key = jax.random.PRNGKey(2)
    from repro.models.model import init_params
    params = init_params(key, cfg)
    batch = make_batch(cfg, 0, 0, B, S)
    l1, _ = loss_fn(params, cfg, batch, loss_chunk=0)
    l2, _ = loss_fn(params, cfg, batch, loss_chunk=8)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda p: loss_fn(p, cfg, batch, loss_chunk=0)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, cfg, batch, loss_chunk=8)[0])(params)
    diff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2))
    )
    assert diff < 1e-4
