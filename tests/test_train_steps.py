"""Compiled DeFT phase steps: equivalence with an explicit gradient-
accumulation reference that replays the PhaseSpec semantics with global
gradients.  This is the convergence-consistency evidence the paper gets
from its ImageNet runs — here it is exact (to f32 reduction order).

Runs on a 1x1 mesh — the full shard_map/psum graph is built; a true
multi-device run of the same check lives in test_multidevice.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import solve_schedule
from repro.core.scheduler import SchedulerConfig
from repro.data.pipeline import make_batch
from repro.models.model import loss_fn
from repro.optim.optimizers import adamw, apply_updates, init_opt_state
from repro.train import (
    assign_buckets,
    init_train_state,
    leaf_bucket_times,
    make_deft_step_fns,
)
from repro.train.steps import ddp_train_step
from repro.core.profiler import HardwareModel

B, S = 4, 32


def _schedule_for(cfg, params, cr):
    bucket_of, nb = assign_buckets(params, cfg, partition_elems=150_000)
    hw = HardwareModel(dp_degree=1)
    times = leaf_bucket_times(params, cfg, bucket_of, nb, hw, S, B)
    scale = cr * (times.fwd_total + times.bwd_total) / max(times.comm_total, 1e-12)
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    return bucket_of, solve_schedule(times, SchedulerConfig())


@pytest.mark.parametrize("cr", [0.5, 1.8])
def test_deft_steps_match_accumulation_reference(single_mesh, cr):
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    state = init_train_state(key, cfg, opt, deft=True, accum_devices=1)
    bucket_of, sched = _schedule_for(cfg, state["params"], cr)
    if cr > 1:
        assert sched.updates_per_period < sched.period

    ref_params = state["params"]
    ref_opt = init_opt_state(opt, ref_params)
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), ref_params
    )
    ref_cur, ref_fut = zeros(), zeros()
    gfn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))

    with single_mesh:
        fns = make_deft_step_fns(cfg, opt, sched, bucket_of, single_mesh)
        for step in range(2 * sched.period):
            batch = make_batch(cfg, 0, step, B, S)
            ph = sched.phases[step % sched.period]
            state, m = fns[step % sched.period](state, batch)

            g = gfn(ref_params, batch)
            if ph.rotate:
                gen = jax.tree.map(
                    lambda a, b: a.astype(jnp.float32) + b, g, ref_fut
                )
                ref_fut = jax.tree.map(jnp.zeros_like, ref_fut)
            else:
                ref_fut = jax.tree.map(
                    lambda f, a: f + a.astype(jnp.float32), ref_fut, g
                )
                gen = None
            if ph.do_update:
                src = ref_cur if ph.update_source == "cur" else gen
                ref_params, ref_opt = apply_updates(
                    opt, ref_params, src, ref_opt,
                    grad_scale=1.0 / ph.update_k,
                )
                ref_cur = gen if ph.update_source == "cur" else \
                    jax.tree.map(jnp.zeros_like, ref_cur)
            elif ph.rotate:
                ref_cur = gen

            diff = max(
                float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state["params"]),
                                jax.tree.leaves(ref_params))
            )
            assert diff < 5e-5, f"step {step}: params diverge by {diff}"
            assert bool(m["updated"]) == ph.do_update


def test_low_cr_full_update_frequency_and_progress(single_mesh):
    """CR << 1: the schedule keeps the baseline update frequency (one
    k=1 update per iteration; only the hard-dependency bucket rides into
    the next iteration's forward — the paper's delayed update) and the
    loss actually descends on the learnable stream."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(1)
    state = init_train_state(key, cfg, opt, deft=True, accum_devices=1)
    bucket_of, sched = _schedule_for(cfg, state["params"], cr=0.05)
    assert sched.updates_per_period == sched.period  # one update per iter
    assert all(k == 1 for k in sched.batch_size_sequence)

    losses = []
    with single_mesh:
        fns = make_deft_step_fns(cfg, opt, sched, bucket_of, single_mesh)
        for step in range(10):
            batch = make_batch(cfg, 0, step, B, S)
            state, m = fns[step % sched.period](state, batch)
            assert bool(m["updated"])
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_loss_chunk_matches_unchunked(single_mesh):
    """Chunked LM-head CE == plain CE (same loss, same gradients)."""
    cfg = reduce_for_smoke(get_config("gemma2-2b"))   # softcaps + tied embed
    key = jax.random.PRNGKey(2)
    from repro.models.model import init_params
    params = init_params(key, cfg)
    batch = make_batch(cfg, 0, 0, B, S)
    l1, _ = loss_fn(params, cfg, batch, loss_chunk=0)
    l2, _ = loss_fn(params, cfg, batch, loss_chunk=8)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda p: loss_fn(p, cfg, batch, loss_chunk=0)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, cfg, batch, loss_chunk=8)[0])(params)
    diff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2))
    )
    assert diff < 1e-4
