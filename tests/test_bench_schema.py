"""BENCH_*.json schema validation: the validator itself, and the
checked-in benchmark files at the repo root (the perf trajectory other
PRs compare against must never silently lose a key)."""
import copy
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, _ROOT)

from benchmarks.bench_schema import (  # noqa: E402
    SCHEMAS,
    validate_data,
    validate_file,
)


def _minimal(schema):
    """Smallest payload satisfying a schema."""
    return {
        k: _minimal(v) if isinstance(v, dict) else 0
        for k, v in schema.items()
    }


@pytest.mark.parametrize("name", sorted(SCHEMAS))
def test_minimal_payload_validates(name):
    assert validate_data(name, _minimal(SCHEMAS[name])) == []


@pytest.mark.parametrize("name", sorted(SCHEMAS))
def test_missing_keys_detected(name):
    schema = SCHEMAS[name]
    data = _minimal(schema)
    # drop one top-level and one nested key
    top = sorted(schema)[0]
    broken = copy.deepcopy(data)
    del broken[top]
    errors = validate_data(name, broken)
    assert any(top in e for e in errors), errors

    nested_parent = next(
        (k for k, v in schema.items() if isinstance(v, dict)), None
    )
    if nested_parent:
        broken = copy.deepcopy(data)
        inner = sorted(schema[nested_parent])[0]
        del broken[nested_parent][inner]
        errors = validate_data(name, broken)
        assert any(f"{nested_parent}.{inner}" in e for e in errors), errors


def test_extra_keys_allowed():
    name = sorted(SCHEMAS)[0]
    data = _minimal(SCHEMAS[name])
    data["a_future_metric"] = 123
    assert validate_data(name, data) == []


def test_unknown_file_rejected():
    assert validate_data("BENCH_bogus.json", {}) != []


def test_wrong_shape_reported():
    name = "BENCH_runtime.json"
    data = _minimal(SCHEMAS[name])
    data["solver"] = 3.0            # mapping expected
    errors = validate_data(name, data)
    assert any("solver" in e and "mapping" in e for e in errors)


@pytest.mark.parametrize("name", sorted(SCHEMAS))
def test_checked_in_bench_files_validate(name):
    """The committed perf-trajectory files conform to their schema."""
    path = os.path.join(_ROOT, name)
    assert os.path.exists(path), (
        f"{name} missing from the repo root — regenerate with "
        f"`python benchmarks/run.py --smoke`"
    )
    assert validate_file(path) == []


def test_bench_repack_entry_floor():
    """The checked-in repack entry exists and the cycle-boundary re-pack
    stays an amortization-friendly one-off: well under the cost of one
    step at a replan-every-100-steps cadence.  Wall clock on a shared
    CPU is load-noisy, so the floors are deliberately loose — the hard
    semantics (bitwise repack identity) live in tests/test_repack.py."""
    path = os.path.join(_ROOT, "BENCH_runtime.json")
    rp = json.load(open(path))["repack"]
    assert rp["n_buckets_a"] != rp["n_buckets_b"]
    assert rp["moved_elems_a_to_b"] > 0
    assert 0 < rp["repack_ms_a_to_b"]
    # one repack per ~100 steps must stay a small fraction of the run
    assert rp["amortized_overhead_at_replan_every_100_steps"] < 0.5, rp


def test_bench_decoupled_entry_floor():
    """The checked-in decoupled entry holds the §12 acceptance
    properties: the split item model's simulated coverage is at least
    the fused chain's (streaming only adds scheduling freedom), the
    measured streamed-AG engine is no slower than the fused-chain engine
    (>= 1.0x floor on the checked-in trajectory), and the pre-forward
    gather burst actually shrank."""
    path = os.path.join(_ROOT, "BENCH_runtime.json")
    dc = json.load(open(path))["decoupled"]
    sim = dc["sim"]
    assert sim["coverage_decoupled"] >= sim["coverage_fused"] - 1e-9, sim
    assert 0.0 <= sim["ag_plan_coverage"] <= 1.0
    assert dc["steps_per_s_ratio_decoupled_vs_fused"] >= 1.0, dc
    assert dc["ag_burst_bytes_delta"] > 0
    assert (dc["ag_burst_bytes_decoupled_peak"]
            < dc["ag_burst_bytes_fused"])
    assert dc["engine"]["decoupled"] is True


def test_bench_precision_entry_floor():
    """The checked-in precision entry holds the §13 acceptance
    properties: the planner-chosen mixed policy's simulated coverage is
    at least the all-f32 row's (shedding wire bytes can only relieve
    the comm capacity), its priced iteration time is no worse, the gate
    passed, and the executed wire bytes per cycle actually shrank.
    steps/s is reported but not floored — CPU-host collectives are
    local memcpys, so the byte win only shows on a real interconnect."""
    path = os.path.join(_ROOT, "BENCH_runtime.json")
    pc = json.load(open(path))["precision"]
    sim = pc["sim"]
    assert sim["coverage_mixed"] >= sim["coverage_f32"] - 1e-9, sim
    assert sim["iteration_time_mixed"] <= sim["iteration_time_f32"] + 1e-12
    assert sim["gate_ok_mixed"] is True
    assert 0.0 < sim["wire_bytes_scale_mixed"] <= 1.0
    assert (pc["wire_bytes_per_cycle_mixed"]
            <= pc["wire_bytes_per_cycle_f32"])
    if pc["engine"]["wire_precision"] != f"f32x{pc['model']['n_buckets']}":
        assert (pc["wire_bytes_per_cycle_mixed"]
                < pc["wire_bytes_per_cycle_f32"])


def test_bench_two_link_entry_floor():
    """The checked-in two_link entry holds the §14 acceptance
    properties: pricing the secondary link can only add communication
    capacity, so the two-link solve's simulated coverage is at least
    the single-link solve's and its iteration time no worse; the forced
    maximal routing actually put traffic on the secondary link; and the
    traced per-link wire bytes match the planned primary/secondary
    split exactly.  steps/s is reported but not floored — on a CPU host
    the chain's n-1 ppermute hops are real memcpys while XLA's fused
    collectives are one, so the chain only wins on real extra wire."""
    path = os.path.join(_ROOT, "BENCH_runtime.json")
    tl = json.load(open(path))["two_link"]
    sim = tl["sim"]
    assert sim["coverage_two_link"] >= sim["coverage_single_link"] - 1e-9, sim
    assert (sim["iteration_time_two_link"]
            <= sim["iteration_time_single_link"] + 1e-12), sim
    assert tl["engine"]["secondary_chain"] == [0, 2, 1, 3]
    assert tl["schedule"]["secondary_slots_forced"] > 0
    # forced maximal routing puts every synced bucket AND every streamed
    # AG item on the secondary link, so primary wire bytes may be zero
    assert tl["wire_bytes_secondary_per_cycle"] > 0
    assert tl["wire_bytes_primary_per_cycle"] >= 0
    assert tl["wire_split_max_abs_error"] == 0.0
    assert tl["wire_split_ok"] is True
    assert tl["steps_per_s_ratio_chain_vs_single_axis"] > 0.0


def test_bench_obs_entry_floor():
    """The checked-in obs entry holds the §11 acceptance properties:
    span-closure reproduces the simulator, the undisturbed attribution
    reads back identity, the divergence drift source leads the EMA
    screen, and per-step tracing stays under the 2% overhead bound."""
    path = os.path.join(_ROOT, "BENCH_obs.json")
    data = json.load(open(path))
    c = data["closure"]
    assert c["iteration_time_exact"] is True
    assert c["cr_error"] < 0.05
    assert c["bubble_abs_error"] < 1e-6
    a = data["attribution"]
    assert abs(a["comp_scale"] - 1.0) < 0.1
    assert abs(a["comm_scale"] - 1.0) < 0.1
    assert a["max_divergence"] < 0.01
    d = data["divergence_lead"]
    assert d["lead_steps"] is not None and d["lead_steps"] >= 1
    assert data["tracing"]["overhead_pct"] < 2.0


def test_check_script_cli():
    """scripts/check_bench_schema.py: exit 0 on the checked-in files,
    exit 1 (with SCHEMA ERROR on stderr) on a broken payload."""
    script = os.path.join(_ROOT, "scripts", "check_bench_schema.py")
    ok = subprocess.run([sys.executable, script], capture_output=True,
                        text=True, cwd=_ROOT)
    assert ok.returncode == 0, ok.stderr

    import tempfile

    with tempfile.TemporaryDirectory() as td:
        bad = os.path.join(td, "BENCH_runtime.json")
        with open(bad, "w") as f:
            json.dump({"solver": {}}, f)
        res = subprocess.run([sys.executable, script, bad],
                             capture_output=True, text=True, cwd=_ROOT)
        assert res.returncode == 1
        assert "SCHEMA ERROR" in res.stderr
