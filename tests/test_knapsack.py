"""Unit + property tests for the knapsack solvers (paper §III.B-C)."""
import itertools

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.knapsack import (
    clear_knapsack_caches,
    greedy_multi_knapsack,
    knapsack_cache_info,
    knapsack_two_link,
    naive_knapsack,
    recursive_knapsack,
    set_knapsack_memoization,
)

times_strategy = st.lists(
    st.floats(min_value=1e-4, max_value=0.5, allow_nan=False), min_size=0,
    max_size=12,
)
cap_strategy = st.floats(min_value=0.0, max_value=2.0, allow_nan=False)


def brute_force(times, capacity):
    best = 0.0
    for r in range(len(times) + 1):
        for combo in itertools.combinations(range(len(times)), r):
            s = sum(times[i] for i in combo)
            if s <= capacity + 1e-12:
                best = max(best, s)
    return best


@given(times_strategy, cap_strategy)
@settings(max_examples=60, deadline=None)
def test_naive_knapsack_feasible_and_unique(times, capacity):
    sel = naive_knapsack(times, capacity)
    assert len(sel) == len(set(sel))
    assert all(0 <= i < len(times) for i in sel)
    assert sum(times[i] for i in sel) <= capacity * 1.001 + 1e-3


@given(
    st.lists(st.integers(min_value=1, max_value=50), min_size=1, max_size=8),
    st.integers(min_value=0, max_value=120),
)
@settings(max_examples=60, deadline=None)
def test_naive_knapsack_optimal_on_integers(wints, cap):
    # integer microsecond-scale values make the DP exact
    times = [w * 1e-6 for w in wints]
    capacity = cap * 1e-6
    sel = naive_knapsack(times, capacity)
    got = sum(times[i] for i in sel)
    assert got == pytest.approx(brute_force(times, capacity), abs=1e-9)


@given(times_strategy, cap_strategy, times_strategy)
@settings(max_examples=40, deadline=None)
def test_recursive_knapsack_at_least_naive(comm, cap, bwd):
    sel_r = recursive_knapsack(comm, cap, bwd)
    sel_n = naive_knapsack(comm, cap)
    s_r = sum(comm[i] for i in sel_r)
    s_n = sum(comm[i] for i in sel_n)
    # Algorithm 1 keeps the better of naive and the recursive refinement
    assert s_r >= s_n - 1e-9
    assert s_r <= cap * 1.001 + 1e-3


@given(
    times_strategy,
    st.lists(st.floats(min_value=0.0, max_value=2.0), min_size=1, max_size=3),
)
@settings(max_examples=60, deadline=None)
def test_greedy_multi_knapsack_feasible(times, caps):
    placed = greedy_multi_knapsack(times, caps)
    seen = set()
    for k, items in placed.items():
        s = sum(times[i] for i in items)
        assert s <= caps[k] + 1e-9
        for i in items:
            assert i not in seen  # an item rides at most one link
            seen.add(i)


@given(times_strategy, cap_strategy, cap_strategy)
@settings(max_examples=40, deadline=None)
def test_two_link_feasible(times, cap_p, cap_s):
    prim, sec = knapsack_two_link(times, cap_p, cap_s)
    assert not set(prim) & set(sec)
    assert sum(times[i] for i in prim) <= cap_p * 1.001 + 1e-3
    assert sum(times[i] for i in sec) <= cap_s + 1e-9


@given(times_strategy, cap_strategy)
@settings(max_examples=40, deadline=None)
def test_memoized_matches_unmemoized(times, capacity):
    """The memo cache must be invisible: identical selections with the
    cache hot, cold, and disabled."""
    prev = set_knapsack_memoization(True)
    try:
        clear_knapsack_caches()
        cold = naive_knapsack(times, capacity)
        hot = naive_knapsack(times, capacity)   # cache hit path
        set_knapsack_memoization(False)
        off = naive_knapsack(times, capacity)
    finally:
        set_knapsack_memoization(prev)
    assert cold == hot == off


@given(
    st.lists(st.integers(min_value=1, max_value=60), min_size=1, max_size=8),
    st.integers(min_value=0, max_value=150),
    st.lists(st.integers(min_value=0, max_value=40), min_size=1, max_size=8),
)
@settings(max_examples=40, deadline=None)
def test_recursive_fast_path_matches_reference(wints, cap, bints):
    """The saturation short-circuit must not change Algorithm 1's answer.
    Integer-microsecond times make the DP exact, so the fast-path result
    must equal a reference recursion without the short-circuit."""
    comm = [w * 1e-6 for w in wints]
    bwd = [b * 1e-6 for b in bints]
    capacity = cap * 1e-6

    def reference(comm_times, remain_time, bwd_times, _depth=0):
        n = len(comm_times)
        if n == 0 or remain_time <= 0:
            return []
        if sum(comm_times) <= remain_time:
            return list(range(n))
        order1 = naive_knapsack(comm_times, remain_time)
        if n == 1 or _depth > 30:
            return order1
        shrink = bwd_times[n - 2] if n - 2 < len(bwd_times) else 0.0
        order2 = reference(
            comm_times[: n - 1], remain_time - shrink, bwd_times, _depth + 1
        )
        s1 = sum(comm_times[i] for i in order1)
        s2 = sum(comm_times[i] for i in order2)
        return order1 if s1 >= s2 else order2

    got = recursive_knapsack(comm, capacity, bwd)
    want = reference(comm, capacity, bwd)
    s = lambda sel: sum(comm[i] for i in sel)
    assert s(got) == pytest.approx(s(want), abs=1e-12)


def test_memoization_caches_repeat_solves():
    set_knapsack_memoization(True)
    clear_knapsack_caches()
    times = [0.01, 0.02, 0.03, 0.04]
    for _ in range(5):
        naive_knapsack(times, 0.05)
    info = knapsack_cache_info()
    assert info.hits >= 4 and info.misses >= 1


def test_knapsack_zero_capacity():
    assert naive_knapsack([0.1, 0.2], 0.0) == []
    assert recursive_knapsack([0.1], 0.0, [0.1]) == []


def test_knapsack_all_fit():
    times = [0.1, 0.2, 0.3]
    sel = naive_knapsack(times, 1.0)
    assert sorted(sel) == [0, 1, 2]


@given(times_strategy, cap_strategy, cap_strategy)
@settings(max_examples=80, deadline=None)
def test_two_link_refinement_never_regresses_greedy(times, cap_p, cap_s):
    """The DP refinement must only ever help: its evicted greedy picks
    are re-offered to residual secondary capacity and the refined split
    is adopted on TOTAL coverage — so the two-link total covered time is
    >= the plain greedy's on every instance (regression: refinement once
    compared primary load only and silently dropped evicted items)."""
    greedy = greedy_multi_knapsack(times, [cap_p, cap_s])
    cov_greedy = sum(times[i] for k in greedy for i in greedy[k])
    prim, sec = knapsack_two_link(times, cap_p, cap_s)
    cov_refined = sum(times[i] for i in prim) + sum(times[i] for i in sec)
    assert cov_refined >= cov_greedy - 1e-9
    # feasibility + disjointness must survive the re-offer step
    assert not set(prim) & set(sec)
    assert sum(times[i] for i in prim) <= cap_p * 1.001 + 1e-3
    assert sum(times[i] for i in sec) <= cap_s + 1e-9


def test_two_link_refinement_reoffers_evicted_items():
    """Deterministic instance where the old refinement lost coverage:
    greedy puts items {3, 1} on the primary link and {0} on the
    secondary; the exact DP re-solve prefers {2, 4} (255.2s > 227.3s),
    evicting BOTH greedy primary picks.  Item 1 (50.2s) still fits the
    secondary link's 113.4s residual — the old code silently dropped it
    (total 430.8s); the re-offer must place it (total 481.0s)."""
    times = [175.604, 50.174, 126.127, 177.076, 129.057]
    cap_p, cap_s = 273.143, 289.04
    prim, sec = knapsack_two_link(times, cap_p, cap_s)
    assert prim == [2, 4]
    assert sec == [0, 1], "evicted item 1 must ride the secondary residual"
    covered = sum(times[i] for i in prim) + sum(times[i] for i in sec)
    assert covered == pytest.approx(480.962)
    greedy = greedy_multi_knapsack(times, [cap_p, cap_s])
    cov_greedy = sum(times[i] for k in greedy for i in greedy[k])
    assert covered > cov_greedy       # strictly better than plain greedy
    assert sum(times[i] for i in prim) <= cap_p * 1.001
    assert sum(times[i] for i in sec) <= cap_s
