"""Unit + property tests for the knapsack solvers (paper §III.B-C)."""
import itertools

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.knapsack import (
    greedy_multi_knapsack,
    knapsack_two_link,
    naive_knapsack,
    recursive_knapsack,
)

times_strategy = st.lists(
    st.floats(min_value=1e-4, max_value=0.5, allow_nan=False), min_size=0,
    max_size=12,
)
cap_strategy = st.floats(min_value=0.0, max_value=2.0, allow_nan=False)


def brute_force(times, capacity):
    best = 0.0
    for r in range(len(times) + 1):
        for combo in itertools.combinations(range(len(times)), r):
            s = sum(times[i] for i in combo)
            if s <= capacity + 1e-12:
                best = max(best, s)
    return best


@given(times_strategy, cap_strategy)
@settings(max_examples=60, deadline=None)
def test_naive_knapsack_feasible_and_unique(times, capacity):
    sel = naive_knapsack(times, capacity)
    assert len(sel) == len(set(sel))
    assert all(0 <= i < len(times) for i in sel)
    assert sum(times[i] for i in sel) <= capacity * 1.001 + 1e-3


@given(
    st.lists(st.integers(min_value=1, max_value=50), min_size=1, max_size=8),
    st.integers(min_value=0, max_value=120),
)
@settings(max_examples=60, deadline=None)
def test_naive_knapsack_optimal_on_integers(wints, cap):
    # integer microsecond-scale values make the DP exact
    times = [w * 1e-6 for w in wints]
    capacity = cap * 1e-6
    sel = naive_knapsack(times, capacity)
    got = sum(times[i] for i in sel)
    assert got == pytest.approx(brute_force(times, capacity), abs=1e-9)


@given(times_strategy, cap_strategy, times_strategy)
@settings(max_examples=40, deadline=None)
def test_recursive_knapsack_at_least_naive(comm, cap, bwd):
    sel_r = recursive_knapsack(comm, cap, bwd)
    sel_n = naive_knapsack(comm, cap)
    s_r = sum(comm[i] for i in sel_r)
    s_n = sum(comm[i] for i in sel_n)
    # Algorithm 1 keeps the better of naive and the recursive refinement
    assert s_r >= s_n - 1e-9
    assert s_r <= cap * 1.001 + 1e-3


@given(
    times_strategy,
    st.lists(st.floats(min_value=0.0, max_value=2.0), min_size=1, max_size=3),
)
@settings(max_examples=60, deadline=None)
def test_greedy_multi_knapsack_feasible(times, caps):
    placed = greedy_multi_knapsack(times, caps)
    seen = set()
    for k, items in placed.items():
        s = sum(times[i] for i in items)
        assert s <= caps[k] + 1e-9
        for i in items:
            assert i not in seen  # an item rides at most one link
            seen.add(i)


@given(times_strategy, cap_strategy, cap_strategy)
@settings(max_examples=40, deadline=None)
def test_two_link_feasible(times, cap_p, cap_s):
    prim, sec = knapsack_two_link(times, cap_p, cap_s)
    assert not set(prim) & set(sec)
    assert sum(times[i] for i in prim) <= cap_p * 1.001 + 1e-3
    assert sum(times[i] for i in sec) <= cap_s + 1e-9


def test_knapsack_zero_capacity():
    assert naive_knapsack([0.1, 0.2], 0.0) == []
    assert recursive_knapsack([0.1], 0.0, [0.1]) == []


def test_knapsack_all_fit():
    times = [0.1, 0.2, 0.3]
    sel = naive_knapsack(times, 1.0)
    assert sorted(sel) == [0, 1, 2]
