"""Layout-agnostic runtime state + cycle-boundary re-pack (DESIGN.md §9).

* `LayoutTransition` span math: repack == re-flatten, bitwise, for any
  pair of layouts of the same tree (property test incl. shard counts);
  A->B->A is the identity.
* The real fused runtime hot-swaps onto a DIFFERENT bucket partition at
  a cycle boundary — no restart — and the post-swap trajectory BIT-MATCHES
  a reference run compiled directly under the new layout.  Covered for
  the replicated flat engine (driven end-to-end by the adaptive
  controller on a BandwidthDrop whose calibrated profile favors another
  partition) and for the sharded RS engine (degenerate 1-shard tier-1
  case; the true 4->2 shard-count change runs in the multidevice test
  at the bottom).
* ZeRO gather skip: phases not preceded by an update reuse the stored
  param gather — bitwise-identical trajectories with N fewer all-gathers
  per skipping phase.
* Checkpoints written under one layout restore under another by routing
  the flat accumulators through the transition.
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.adapt import (
    AdaptConfig,
    AdaptiveController,
    BandwidthDrop,
    RepartitionConfig,
    Repartitioner,
    SyntheticTelemetrySource,
)
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import feedback_solve
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.models.model import init_params
from repro.optim.optimizers import adamw
from repro.train import (
    DeftRuntime,
    assign_buckets,
    build_bucket_layout,
    build_layout_transition,
    build_leaf_time_model,
    flatten_buckets,
    leaf_bucket_times,
    repack_buffers,
    unflatten_buckets,
)

WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
B, S = 4, 32


# ---------------------------------------------------------------------------
# LayoutTransition span math
# ---------------------------------------------------------------------------
def _tree():
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (37, 9)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (13,)),
        "h": jax.random.normal(jax.random.fold_in(key, 2), (200,)),
        "u": jax.random.normal(jax.random.fold_in(key, 3), (5, 7, 3)),
    }


def test_transition_spans_cover_dst_exactly():
    tree = _tree()
    src = build_bucket_layout(tree, (0, 1, 1, 0), 2)
    dst = build_bucket_layout(tree, (0, 1, 2, 2), 3, shard_count=2)
    tr = build_layout_transition(src, dst)
    for b in range(dst.n_buckets):
        covered = sorted((c.dst_off, c.dst_off + c.length)
                         for c in tr.copies[b])
        cursor = 0
        for lo, hi in covered:
            assert lo == cursor     # dense, in order
            cursor = hi
        assert cursor == dst.sizes[b]
    # reverse is the inverse mapping
    back = tr.reverse()
    assert back.src == dst and back.dst == src


def test_shard_count_only_transition_is_one_span_per_bucket():
    """Same partition, different shard count: every bucket's valid data
    is one contiguous run, so the transition merges it to ONE SpanCopy
    (padding alone changes)."""
    tree = _tree()
    a = build_bucket_layout(tree, (0, 1, 1, 0), 2, shard_count=4)
    b = build_bucket_layout(tree, (0, 1, 1, 0), 2, shard_count=2)
    tr = build_layout_transition(a, b)
    for spans in tr.copies:
        assert len(spans) == 1
        assert spans[0].src_off == 0 and spans[0].dst_off == 0


def test_identity_transition_marks_all_identical():
    tree = _tree()
    lay = build_bucket_layout(tree, (0, 1, 1, 0), 2)
    tr = build_layout_transition(lay, lay)
    assert all(tr.identical)
    assert tr.moved_elems == 0
    bufs = flatten_buckets(lay, jax.tree.leaves(tree))
    out = repack_buffers(tr, bufs)
    for a, b in zip(out, bufs):
        assert a is b               # pass-through enables donation alias


def test_transition_rejects_different_trees():
    t1, t2 = _tree(), {"x": jnp.zeros((3, 3))}
    l1 = build_bucket_layout(t1, (0, 1, 1, 0), 2)
    l2 = build_bucket_layout(t2, (0,), 1)
    with pytest.raises(ValueError, match="same parameter tree"):
        build_layout_transition(l1, l2)


@given(
    st.integers(min_value=0, max_value=10_000),
    st.lists(st.integers(min_value=0, max_value=3), min_size=4, max_size=4),
    st.lists(st.integers(min_value=0, max_value=3), min_size=4, max_size=4),
    st.integers(min_value=0, max_value=2),
    st.integers(min_value=0, max_value=2),
)
@settings(max_examples=25, deadline=None)
def test_repack_is_reflatten_and_roundtrips(seed, bo_a, bo_b, sh_a, sh_b):
    """Property: for ANY two layouts of the same tree (random bucket
    assignments, random shard counts), repacking A's buffers through the
    transition equals flattening directly under B, bitwise; and A->B->A
    is the identity."""
    tree = _tree()
    # normalize assignments so bucket ids are dense 0..n-1
    def dense(bo):
        ids = {b: i for i, b in enumerate(dict.fromkeys(bo))}
        return tuple(ids[b] for b in bo), len(ids)

    bo_a, nb_a = dense(bo_a)
    bo_b, nb_b = dense(bo_b)
    lay_a = build_bucket_layout(tree, bo_a, nb_a, shard_count=2 ** sh_a)
    lay_b = build_bucket_layout(tree, bo_b, nb_b, shard_count=2 ** sh_b)
    rng = np.random.default_rng(seed)
    leaves = [jnp.asarray(rng.normal(size=l.shape).astype(np.float32))
              for l in jax.tree.leaves(tree)]
    bufs_a = flatten_buckets(lay_a, leaves)
    bufs_b = flatten_buckets(lay_b, leaves)
    tr = build_layout_transition(lay_a, lay_b)
    got_b = repack_buffers(tr, bufs_a)
    for g, w in zip(got_b, bufs_b):
        assert g.shape == w.shape
        assert bool(jnp.array_equal(g, w))
    back = repack_buffers(tr.reverse(), got_b)
    for g, w in zip(back, bufs_a):
        assert bool(jnp.array_equal(g, w))


def test_repack_preserves_dtype():
    """Pad/gap fills match the src dtype — an f32 zero concatenated into
    a bf16 buffer would silently promote the whole dst buffer."""
    tree = _tree()
    a = build_bucket_layout(tree, (0, 1, 1, 0), 2)
    b = build_bucket_layout(tree, (0, 0, 1, 1), 2, shard_count=2)
    bufs = [x.astype(jnp.bfloat16)
            for x in flatten_buckets(a, jax.tree.leaves(tree))]
    out = repack_buffers(build_layout_transition(a, b), bufs)
    assert all(x.dtype == jnp.bfloat16 for x in out)


def test_repack_rows_accumulator_axis():
    """cur/fut carry a leading device axis: the repack remaps the LAST
    axis only, rows independently."""
    tree = _tree()
    a = build_bucket_layout(tree, (0, 1, 1, 0), 2)
    b = build_bucket_layout(tree, (0, 0, 1, 1), 2, shard_count=2)
    leaves = jax.tree.leaves(tree)
    rows_a = [jnp.stack([f, -2.0 * f])
              for f in flatten_buckets(a, leaves)]
    tr = build_layout_transition(a, b)
    got = repack_buffers(tr, rows_a)
    want = [jnp.stack([f, -2.0 * f]) for f in flatten_buckets(b, leaves)]
    for g, w in zip(got, want):
        assert bool(jnp.array_equal(g, w))


# ---------------------------------------------------------------------------
# Runtime hot-swap onto a different partition (cycle-boundary re-pack)
# ---------------------------------------------------------------------------
def _tiny_cfg():
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )


def _plan(cfg, params, partition_elems, cr=1.8):
    bucket_of, nb = assign_buckets(params, cfg,
                                   partition_elems=partition_elems)
    t = leaf_bucket_times(params, cfg, bucket_of, nb,
                          HardwareModel(dp_degree=2), S, B)
    scale = cr * (t.fwd_total + t.bwd_total) / t.comm_total
    t = BucketTimes(t.fwd, t.bwd, tuple(c * scale for c in t.comm))
    sched, _, scfg, _ = feedback_solve(t, WALK)
    return bucket_of, nb, t, sched, scfg


def _run_reference_with_swap(cfg, opt, key, sched_a, lay_a, sched_b, lay_b,
                             mesh, swap_step, n_steps, fsdp=False):
    """Reference trajectory: layout-A runtime to the swap boundary, an
    explicit repack, then a runtime compiled DIRECTLY under layout B."""
    rt_a = DeftRuntime(cfg, opt, sched_a, lay_a, mesh, fsdp=fsdp)
    state = rt_a.init_state(key)
    rt_b = DeftRuntime(cfg, opt, sched_b, lay_b, mesh, fsdp=fsdp)
    for step in range(swap_step):
        state, _ = rt_a.step(step, state, make_batch(cfg, 0, step, B, S))
    state = rt_b.repack_state(state, build_layout_transition(lay_a, lay_b))
    for step in range(swap_step, n_steps):
        state, _ = rt_b.step(step - swap_step, state,
                             make_batch(cfg, 0, step, B, S))
    return rt_b, state


@pytest.mark.parametrize("fsdp", [False, True],
                         ids=["replicated", "sharded-rs"])
def test_partition_hot_swap_bitwise(single_mesh, fsdp):
    """prepare_swap(layout=...) re-packs the donated state at the cycle
    boundary; the resulting trajectory bit-matches the reference that
    runs layout A, repacks explicitly, and continues under a runtime
    compiled directly for layout B.  Both flat engines (the sharded one
    in its degenerate 1-shard tier-1 form; real shards run in the
    multidevice test)."""
    cfg = _tiny_cfg()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    shards = 1
    bo_a, nb_a, _, sched_a, _ = _plan(cfg, params, 20_000)
    bo_b, nb_b, _, sched_b, _ = _plan(cfg, params, 60_000)
    assert bo_a != bo_b, "partitions must differ for this test"
    lay_a = build_bucket_layout(params, bo_a, nb_a, shard_count=shards)
    lay_b = build_bucket_layout(params, bo_b, nb_b, shard_count=shards)

    rt = DeftRuntime(cfg, opt, sched_a, lay_a, single_mesh, fsdp=fsdp)
    state = rt.init_state(key)
    n_steps = 2 * sched_a.period + 2 * sched_b.period
    with jax.set_mesh(single_mesh):
        for step in range(sched_a.period + 1):
            state, _ = rt.step(step, state, make_batch(cfg, 0, step, B, S))
        info = rt.prepare_swap(sched_b, state, make_batch(cfg, 0, 0, B, S),
                               layout=lay_b)
        assert info["layout_change"]
        assert info["n_buckets"] == (nb_a, nb_b)
        for step in range(sched_a.period + 1, n_steps):
            state, _ = rt.step(step, state, make_batch(cfg, 0, step, B, S))

        assert rt.layout_swaps == 1 and rt.layout == lay_b
        swap = rt.swap_log[0]
        assert swap["step"] % sched_a.period == 0
        assert swap["repack_s"] is not None and swap["repack_s"] > 0

        rt_ref, ref_state = _run_reference_with_swap(
            cfg, opt, key, sched_a, lay_a, sched_b, lay_b, single_mesh,
            swap["step"], n_steps, fsdp=fsdp,
        )
    for a, b in zip(jax.tree.leaves(rt.params_tree(state)),
                    jax.tree.leaves(rt_ref.params_tree(ref_state))):
        assert bool(jnp.array_equal(a, b)), \
            "partition hot-swap diverged from the direct-layout reference"


def test_adaptive_repartition_end_to_end(single_mesh):
    """The acceptance scenario: a BandwidthDrop whose calibrated profile
    favors a DIFFERENT partition drives the controller to a
    partition-changing replan; the runtime hot-swaps (repack at a cycle
    boundary, no restart) and the post-swap trajectory bit-matches the
    reference compiled directly under the new layout."""
    cfg = _tiny_cfg()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    pe = 20_000
    bo, nb, times, schedule, scfg = _plan(cfg, params, pe)
    lay = build_bucket_layout(params, bo, nb)

    # leaf model consistent with `times` (same CR rescale helper)
    model = build_leaf_time_model(params, cfg, HardwareModel(dp_degree=2),
                                  S, B)
    model = model.with_coverage_rate(bo, nb, 1.8)
    assert model.bucket_times(bo, nb) == times
    rp = Repartitioner(model, RepartitionConfig(base_partition_elems=pe))

    drop = BandwidthDrop(step=4, comm_scale=3.0)
    src = SyntheticTelemetrySource(times, drop)
    ctrl = AdaptiveController(
        times, schedule, scfg, walk=WALK,
        cfg=AdaptConfig(warmup_steps=2, check_every=2, cooldown_steps=100,
                        min_loss_samples=10**9),   # timing trigger only
        repartitioner=rp, bucket_of=bo,
    )

    rt = DeftRuntime(cfg, opt, schedule, lay, single_mesh)
    state = rt.init_state(key)
    n_steps = 6 * schedule.period + 8
    event = None
    run_base = None
    new_lay = None
    with jax.set_mesh(single_mesh):
        for step in range(n_steps):
            batch = make_batch(cfg, 0, step, B, S)
            state, m = rt.step(step, state, batch)
            wall = src.wall_time(step, ctrl.schedule, ctrl.scheduler_cfg,
                                 rt.last_phase, solve_times=ctrl.times,
                                 run_base=run_base)
            ev = ctrl.observe(step, rt.last_phase, wall)
            if ev is not None and ev.changed:
                assert event is None, "cooldown should allow one swap"
                event = ev
                assert ev.partition_changed, \
                    "calibrated drop profile should favor repartitioning"
                run_base = rp.base_times_for(ev.partition)
                new_lay = build_bucket_layout(
                    params, ev.partition.bucket_of, ev.partition.n_buckets
                )
                rt.prepare_swap(ev.schedule, state, batch,
                                layout=new_lay, background=False)

        assert event is not None, "no replan despite 3x bandwidth drop"
        assert event.new_n_buckets != nb
        assert event.verdict.ok          # Preserver gated the partition
        st = rt.stats()
        assert st["hot_swaps"] == 1 and st["layout_swaps"] == 1
        swap = rt.swap_log[0]
        assert swap["n_buckets"] == event.new_n_buckets
        assert rt.period == event.schedule.period

        rt_ref, ref_state = _run_reference_with_swap(
            cfg, opt, key, schedule, lay, event.schedule, new_lay,
            single_mesh, swap["step"], n_steps,
        )
    for a, b in zip(jax.tree.leaves(rt.params_tree(state)),
                    jax.tree.leaves(rt_ref.params_tree(ref_state))):
        assert bool(jnp.array_equal(a, b)), \
            "adaptive repartition diverged from the direct-layout reference"


# ---------------------------------------------------------------------------
# ZeRO gather skip (sharded flat engine)
# ---------------------------------------------------------------------------
def _sharded_setup(cr=1.8, pe=40_000):
    cfg = _tiny_cfg()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    bo, nb, _, sched, _ = _plan(cfg, params, pe, cr=cr)
    lay = build_bucket_layout(params, bo, nb, shard_count=1)
    return cfg, opt, key, sched, lay


def test_gather_reuse_masks_are_static_and_schedule_derived(single_mesh):
    cfg, opt, key, sched, lay = _sharded_setup()
    rt = DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True)
    masks = rt._gather_reuse_masks(sched)
    assert len(masks) == sched.period
    assert not any(masks[0]), "position 0 must always gather"
    for t in range(1, sched.period):
        expect = not sched.phases[t - 1].do_update
        assert all(m == expect for m in masks[t])
    # off switch: no masks, no pgather in the state
    rt_off = DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True,
                         gather_skip=False)
    assert rt_off._gather_reuse_masks(sched) == [None] * sched.period
    assert "pgather" not in rt_off.init_state(key)
    # a schedule with nothing to reuse defaults the cache OFF: an unread
    # cache cannot be donated and would ride every step for nothing
    bo1, nb1, _, sched1, _ = _plan(cfg, init_params(key, cfg), 20_000)
    if not DeftRuntime._schedule_has_reuse(sched1):
        lay1 = build_bucket_layout(init_params(key, cfg), bo1, nb1,
                                   shard_count=1)
        rt1 = DeftRuntime(cfg, opt, sched1, lay1, single_mesh, fsdp=True)
        assert not rt1.stats()["gather_skip"]
        assert "pgather" not in rt1.init_state(key)
    # explicit request on a non-RS engine fails loudly
    with pytest.raises(ValueError, match="gather_skip"):
        DeftRuntime(cfg, opt, sched, lay, single_mesh, gather_skip=True)


def test_gather_skip_bitwise_and_fewer_allgathers(single_mesh):
    """Skip ON vs OFF: bit-identical trajectories (the reused gather IS
    the bytes a fresh all-gather would produce), and each reuse-phase
    jaxpr contains exactly n_buckets fewer all_gather collectives."""
    cfg, opt, key, sched, lay = _sharded_setup()
    if not any(not ph.do_update for ph in sched.phases[:-1]) \
            or sched.period < 2:
        pytest.skip("schedule has no reusable phase at this config")
    with jax.set_mesh(single_mesh):
        rt_on = DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True)
        rt_off = DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True,
                             gather_skip=False)
        assert any(any(m) for m in rt_on._gather_reuse_masks(sched))
        s_on, s_off = rt_on.init_state(key), rt_off.init_state(key)
        for step in range(2 * sched.period + 1):
            batch = make_batch(cfg, 0, step, B, S)
            s_on, _ = rt_on.step(step, s_on, batch)
            s_off, _ = rt_off.step(step, s_off, batch)
        for a, b in zip(jax.tree.leaves(rt_on.params_tree(s_on)),
                        jax.tree.leaves(rt_off.params_tree(s_off))):
            assert bool(jnp.array_equal(a, b)), "gather skip changed math"

        # static collective count: reuse phases drop one all_gather per
        # bucket (the ZeRO param gather)
        reuse_t = next(t for t in range(1, sched.period)
                       if not sched.phases[t - 1].do_update)
        batch = make_batch(cfg, 0, 0, B, S)

        def subjaxprs(val):
            import jax.core as jc

            if isinstance(val, jc.ClosedJaxpr):
                yield val.jaxpr
            elif isinstance(val, jc.Jaxpr):
                yield val
            elif isinstance(val, (list, tuple)):
                for v in val:
                    yield from subjaxprs(v)

        def count_allgather_eqns(jaxpr):
            n = 0
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == "all_gather":
                    n += 1
                for val in eqn.params.values():
                    for sub in subjaxprs(val):
                        n += count_allgather_eqns(sub)
            return n

        def count_allgather(rt, state, t):
            key_t = rt._schedule_keys(rt.schedule)[t]
            jaxpr = jax.make_jaxpr(
                lambda s, bb: rt._entries[key_t].jitted(s, bb)
            )(state, batch)
            return count_allgather_eqns(jaxpr.jaxpr)

        n_on = count_allgather(rt_on, s_on, reuse_t)
        n_off = count_allgather(rt_off, s_off, reuse_t)
        assert n_off - n_on == lay.n_buckets, (n_on, n_off)


# ---------------------------------------------------------------------------
# Cross-layout checkpoint restore
# ---------------------------------------------------------------------------
def test_checkpoint_restores_across_layouts(single_mesh, tmp_path):
    """A checkpoint written under layout A restores into a layout-B
    runtime by routing the flat accumulators through the transition —
    bitwise equal to re-flattening the same values under B."""
    from repro.checkpoint.checkpoint import restore, save

    cfg = _tiny_cfg()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    bo_a, nb_a, _, sched_a, _ = _plan(cfg, params, 20_000)
    bo_b, nb_b, _, sched_b, _ = _plan(cfg, params, 60_000)
    lay_a = build_bucket_layout(params, bo_a, nb_a)
    lay_b = build_bucket_layout(params, bo_b, nb_b)

    rt_a = DeftRuntime(cfg, opt, sched_a, lay_a, single_mesh)
    state = rt_a.init_state(key)
    with jax.set_mesh(single_mesh):
        for step in range(sched_a.period + 1):
            state, _ = rt_a.step(step, state, make_batch(cfg, 0, step, B, S))
    save(str(tmp_path), 7, rt_a.state_to_tree(state))

    rt_b = DeftRuntime(cfg, opt, sched_b, lay_b, single_mesh)
    like = rt_b.checkpoint_struct(lay_a)
    ts = restore(str(tmp_path), 7, like)
    restored = rt_b.tree_to_state(ts, src_layout=lay_a)

    # independent reference: unflatten each accumulator row under A and
    # re-flatten under B (no LayoutTransition involved)
    def reflatten_rows(rows_a):
        out = []
        n_rows = rows_a[0].shape[0]
        for r in range(n_rows):
            leaves = unflatten_buckets(lay_a, [x[r] for x in rows_a])
            out.append(flatten_buckets(lay_b, leaves))
        return [jnp.stack([out[r][b] for r in range(n_rows)])
                for b in range(lay_b.n_buckets)]

    want_pbuf = flatten_buckets(
        lay_b, unflatten_buckets(lay_a, state["pbuf"]))
    for got, want in zip(restored["pbuf"], want_pbuf):
        assert bool(jnp.array_equal(got, want))
    for name in ("cur", "fut"):
        for got, want in zip(restored[name], reflatten_rows(state[name])):
            assert bool(jnp.array_equal(got, want))
    # and the restored state actually trains under B
    with jax.set_mesh(single_mesh):
        restored, m = rt_b.step(0, restored, make_batch(cfg, 0, 0, B, S))
    assert bool(jnp.isfinite(m["loss"]))


# ---------------------------------------------------------------------------
# Shard-count change (4 -> 2) on forced devices
# ---------------------------------------------------------------------------
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import dataclasses
from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import solve_schedule
from repro.core.scheduler import SchedulerConfig
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.optim.optimizers import adamw
from repro.train import (DeftRuntime, assign_buckets, build_bucket_layout,
                         build_layout_transition, init_train_state,
                         leaf_bucket_times)

cfg = reduce_for_smoke(get_config("qwen3-4b"))
opt = adamw(1e-3)
key = jax.random.PRNGKey(0)
B, S = 8, 32
probe = init_train_state(key, cfg, opt)
bucket_of, nb = assign_buckets(probe["params"], cfg, partition_elems=150_000)
times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                          HardwareModel(dp_degree=4), S, 2)
scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
times = BucketTimes(times.fwd, times.bwd, tuple(c * scale for c in times.comm))
sched = solve_schedule(times, SchedulerConfig())

mesh4 = jax.make_mesh((4, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh2 = jax.make_mesh((2, 2, 1), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 3)
lay4 = build_bucket_layout(probe["params"], bucket_of, nb, shard_count=4)
lay2 = build_bucket_layout(probe["params"], bucket_of, nb, shard_count=2)
tr = build_layout_transition(lay4, lay2)
half = sched.period
total = 2 * sched.period

# mid-run shard-count change: 4-shard engine on mesh4, repack into the
# 2-shard engine on mesh2 (same schedule — the partition is unchanged)
rt4 = DeftRuntime(cfg, opt, sched, lay4, mesh4, fsdp=True)
with jax.set_mesh(mesh4):
    state = rt4.init_state(key)
    for b, a in enumerate(state["pbuf"]):
        assert {s.data.size for s in a.addressable_shards} \
            == {lay4.shard_sizes[b]}
    for step in range(half):
        state, _ = rt4.step(step, state, make_batch(cfg, 0, step, B, S))
rt2 = DeftRuntime(cfg, opt, sched, lay2, mesh2, fsdp=True)
with jax.set_mesh(mesh2):
    state = rt2.repack_state(state, tr)
    # residency after the repack: split over mesh2's 'data' (2 shards)
    for b, a in enumerate(state["pbuf"]):
        assert a.sharding.spec == P("data"), a.sharding
        assert {s.data.size for s in a.addressable_shards} \
            == {lay2.shard_sizes[b]}
    for step in range(half, total):
        state, _ = rt2.step(step - half, state,
                            make_batch(cfg, 0, step, B, S))

# reference: the whole run from scratch under the 2-shard engine
rt2b = DeftRuntime(cfg, opt, sched, lay2, mesh2, fsdp=True)
with jax.set_mesh(mesh2):
    ref = rt2b.init_state(key)
    for step in range(total):
        ref, _ = rt2b.step(step, ref, make_batch(cfg, 0, step, B, S))

diff = max(float(jnp.max(jnp.abs(a - b)))
           for a, b in zip(jax.tree.leaves(rt2.params_tree(state)),
                           jax.tree.leaves(rt2b.params_tree(ref))))
# same update math; only the collective summation grouping differs
# between psum(data=4) and RS(data=2)+psum(pod=2)
assert diff < 1e-5, f"shard-count change diverged: {diff}"
print(f"SHARD_REPACK_OK diff={diff:.2e}")
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_shard_count_change_4_to_2_on_forced_devices(tmp_path):
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script), src],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARD_REPACK_OK" in out.stdout
