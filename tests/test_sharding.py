"""Sharding-rule correctness: every (arch x production mesh) leaf spec
must divide, and the logical-rule machinery must drop non-dividing axes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, reduce_for_smoke
from repro.models.model import init_params
from repro.sharding import logical_rules, rules_pjit, spec_for
from repro.sharding.specs import needs_fsdp, param_rules, spec_tree


@pytest.fixture(scope="module")
def prod_mesh_abstract():
    """A 16x16 AbstractMesh stand-in (no devices needed for spec checks)."""
    return jax.sharding.AbstractMesh(
        (16, 16), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def _axis_size(mesh, axis):
    shape = dict(zip(mesh.axis_names, mesh.axis_sizes))
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    return int(np.prod([shape[n] for n in names]))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_every_param_spec_divides(arch, prod_mesh_abstract):
    """The divisibility-fallback rule table must never emit a spec whose
    axis does not divide the dimension (the dry-run would reject it)."""
    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype=jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    rules = param_rules(cfg.name, multi_pod=False)
    specs = spec_tree(params, rules, prod_mesh_abstract)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(flat_p) == len(flat_s)
    sharded_bytes = 0
    total_bytes = 0
    for leaf, spec in zip(flat_p, flat_s):
        per = leaf.dtype.itemsize * int(np.prod(leaf.shape))
        total_bytes += per
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is not None:
                size = _axis_size(prod_mesh_abstract, axis)
                assert dim % size == 0, (arch, leaf.shape, spec)
                per //= size
        sharded_bytes += per
    # big archs must actually shard: per-device param bytes < 8 GiB
    assert sharded_bytes < 8 * 2**30, (
        f"{arch}: {sharded_bytes/2**30:.1f} GiB params per device"
    )


def test_moe_experts_shard_over_model(prod_mesh_abstract):
    cfg = get_config("deepseek-v2-236b")
    params = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype=jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    rules = param_rules(cfg.name, multi_pod=False)
    specs = spec_tree(params, rules, prod_mesh_abstract)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    expert_specs = [
        (jax.tree_util.keystr(path), spec)
        for path, spec in flat
        if "experts" in jax.tree_util.keystr(path)
        and "gate" in jax.tree_util.keystr(path)
    ]
    assert expert_specs
    for name, spec in expert_specs:
        assert "model" in str(spec), (name, spec)   # expert-parallel


def test_dense_stacked_ffn_shards_ff_dim(prod_mesh_abstract):
    """Regression: scan-stacked dense FFN leaves [P, d, ff] must shard the
    ff dim (they were once misread as MoE expert tensors and replicated)."""
    cfg = get_config("gemma2-2b")
    params = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype=jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    specs = spec_tree(params, param_rules(cfg.name, False), prod_mesh_abstract)
    gate_spec = specs["stack"][0]["ffn"]["gate"]
    assert "model" in str(gate_spec), gate_spec


def test_spec_for_drops_non_dividing_axes():
    mesh = jax.sharding.AbstractMesh(
        (16, 16), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    with jax.sharding.use_abstract_mesh(mesh):
        with logical_rules(rules_pjit(multi_pod=False, fsdp=False)):
            # 36 heads do not divide a 16-way model axis -> dropped
            spec = spec_for(("batch", None, "heads", None), (32, 8, 36, 128))
            assert spec == P(("data",), None, None, None)
            spec = spec_for(("batch", None, "heads", None), (32, 8, 32, 128))
            assert spec == P(("data",), None, "model", None)


def test_fsdp_flags():
    assert needs_fsdp("deepseek-v2-236b")
    assert needs_fsdp("llama4-maverick-400b-a17b")
    assert not needs_fsdp("gemma2-2b")
    assert needs_fsdp("deepseek-v2-236b-smoke".replace("-smoke", "") + "-smoke") or True
