"""Preserver (paper §IV.C): Gaussian-walk-with-rebound loss quantification."""
import math
import random

import pytest

from repro.core.preserver import (
    PreserverVerdict,
    WalkParams,
    check_schedule,
    estimate_walk_params_from_losses,
    expected_next_state,
    rollout,
    verdict_ok,
)


def monte_carlo_next(s_t, batch_mult, p: WalkParams, n=200_000, seed=0):
    """Simulate the rebound walk directly."""
    rng = random.Random(seed)
    b_eff = p.batch * batch_mult
    tot = 0.0
    for _ in range(n):
        step = rng.gauss(p.mu, p.sigma / math.sqrt(b_eff))
        s = s_t - p.eta * step
        if s < p.s_star:
            s = 2 * p.s_star - s  # rebound
        tot += s
    return tot / n


@pytest.mark.parametrize("batch_mult", [1.0, 2.0, 8.0])
def test_expected_next_state_matches_monte_carlo(batch_mult):
    p = WalkParams(s0=1.0, s_star=0.0, eta=0.05, mu=2.0, sigma=30.0, batch=64)
    analytic = expected_next_state(p.s0, batch_mult, p)
    sim = monte_carlo_next(p.s0, batch_mult, p)
    assert analytic == pytest.approx(sim, rel=0.02)


def test_larger_batch_reduces_expected_loss_near_objective():
    """Near S*, noise dominates — larger batches (smaller noise) land
    closer to the objective (the paper's Table V effect)."""
    p = WalkParams(s0=0.05, s_star=0.0, eta=0.01, mu=1.0, sigma=50.0, batch=64)
    e1 = expected_next_state(p.s0, 1.0, p)
    e8 = expected_next_state(p.s0, 8.0, p)
    assert e8 < e1


def test_far_from_objective_batch_barely_matters():
    p = WalkParams(s0=10.0, s_star=0.0, eta=0.01, mu=1.0, sigma=10.0, batch=256)
    e1 = expected_next_state(p.s0, 1.0, p)
    e8 = expected_next_state(p.s0, 8.0, p)
    assert e1 == pytest.approx(e8, rel=1e-3)


def test_identical_sequences_pass():
    p = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    v = check_schedule([1, 1, 1, 1], period=4, params=p, eps=0.01)
    assert v.ok
    assert v.ratio == pytest.approx(1.0, abs=1e-9)


def test_merging_aggressiveness_monotone():
    """The ratio drifts further from 1 the more generations merge; a tight
    eps rejects aggressive merging while accepting the identical sequence."""
    p = WalkParams(s0=0.2, s_star=0.0, eta=0.05, mu=0.5, sigma=80.0, batch=16)
    dev = []
    for seq, period in (([1] * 4, 4), ([2, 1, 1], 4), ([4], 4), ([16], 16)):
        v = check_schedule(seq, period=period, params=p, eps=0.01)
        dev.append(abs(v.ratio - 1.0))
    assert dev == sorted(dev)
    assert dev[0] < 1e-9          # identical sequence is exact
    aggressive = check_schedule([16], period=16, params=p, eps=0.0005)
    assert not aggressive.ok


def test_empty_schedule_fails():
    p = WalkParams(s0=1.0)
    v = check_schedule([], period=4, params=p)
    assert not v.ok and v.ratio == float("inf")


def test_estimate_walk_params_roundtrip():
    losses = [5.0, 4.5, 4.2, 3.9, 3.7, 3.4, 3.2]
    p = estimate_walk_params_from_losses(losses, eta=0.01, batch=64)
    assert p.s0 == losses[-1]
    assert p.mu > 0 and p.sigma >= 0


# ---------------------------------------------------------------------------
# Edge cases the online control plane leans on (ISSUE 2 satellites)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("period", [1, 4, 17])
def test_degenerate_m_equals_n_is_exact_noop(period):
    """m == N (every iteration updates with k=1): O_D IS O_B, so the
    verdict must be an exact identity — ratio exactly 1.0 and ok even at
    eps=0, including near-S* parameters where both rollouts approach
    s_star and a naive ratio would be 0/0."""
    for p in (
        WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256),
        WalkParams(s0=1e-12, s_star=0.0, eta=0.5, mu=5.0, sigma=1e-6, batch=1),
    ):
        v = check_schedule([1] * period, period=period, params=p, eps=0.0)
        assert v.ok
        assert v.ratio == 1.0                  # exact, not approx
        assert v.e_baseline == v.e_deft


def test_eps_boundary_is_inclusive():
    """The acceptance band [1-eps, 1+eps] includes its endpoints; one ulp
    outside is rejected."""
    eps = 0.01
    assert verdict_ok(1.0 + eps, eps)
    assert verdict_ok(1.0 - eps, eps)
    assert not verdict_ok(math.nextafter(1.0 + eps, 2.0), eps)
    assert not verdict_ok(math.nextafter(1.0 - eps, 0.0), eps)
    assert verdict_ok(1.0, 0.0)


def test_check_schedule_with_measured_walk_params():
    """The measured-WalkParams path (Fig. 7 'convergence info' edge): a
    walk fit from an observed loss trace feeds check_schedule directly.
    Identical sequences stay exact; merged sequences get a real verdict
    whose deviation grows with merging, same as under analytic params."""
    rng = random.Random(7)
    losses = [abs(rng.gauss(0.05, 0.03)) for _ in range(64)]
    w = estimate_walk_params_from_losses(losses, eta=0.05, batch=16)
    assert w.s0 == losses[-1] and w.sigma > 0

    exact = check_schedule([1, 1, 1, 1], period=4, params=w, eps=0.0)
    assert exact.ok and exact.ratio == 1.0

    mild = check_schedule([2, 1, 1], period=4, params=w, eps=1e9)
    strong = check_schedule([4], period=4, params=w, eps=1e9)
    assert abs(strong.ratio - 1.0) >= abs(mild.ratio - 1.0)
    # a tight eps rejects the aggressive merge under the measured walk
    assert not check_schedule([4], period=4, params=w, eps=1e-6).ok
