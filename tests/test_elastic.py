"""Fault-tolerant elastic control plane (DESIGN.md §10).

* HealthMonitor policies: absolute-timeout dead detection, EWMA-ratio
  straggler detection + recovery, uniform-drift-is-not-a-straggler, the
  explicit preemption notice.
* FaultScenario determinism and the KillMidCheckpoint damage model.
* Accumulator-row folding preserves the global-mean gradient across any
  mesh width change.
* ElasticController degradation ladder: scale-down (sharded) ->
  fallback-replicated -> checkpoint-halt, all Preserver-gated.
* Coordinator armed-plan invariants: cascading faults extend (never
  resurrect), capacity returns merge with (never clobber) a pending
  fault plan, straggler recovery fully restores the shard.
* Atomic checkpoints: a truncated (killed-mid-write) newest step is
  skipped and resume picks the previous complete one.
* Hardened resume: a schedule-digest mismatch falls back to cycle-start
  restore instead of misreading mid-generation accumulators.
* prepare_swap failure paths: an injected background compile exception
  surfaces in swap_log and retries; an exhausted retry budget leaves the
  old plan running and a later replan succeeds.
* Engine-fallback migration (sharded -> replicated flat) on one device
  matches a reference run compiled directly for the fallback engine.
* Chaos (subprocess, forced devices): device-drop 4->2 scale-down whose
  post-fault trajectory matches a from-scratch 2-shard run from the
  repacked state, the symmetric 2->4 scale-up, the A->B->A state round
  trip, a straggler-triggered 4->3 scale-down, and a cascading
  two-preemption window folding into one 4->2 scale-down.
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    is_complete,
    latest_step,
    restore,
    save,
    save_layout_descriptor,
    schedule_digest,
    valid_steps,
)
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import feedback_solve
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.elastic import (
    BandwidthCollapse,
    CapacityReturn,
    DeviceDrop,
    ElasticController,
    ElasticCoordinator,
    ElasticHalt,
    FaultScenario,
    HealthConfig,
    HealthMonitor,
    StragglerSlowdown,
    fold_accum_rows,
    migrate_state,
    truncate_checkpoint,
)
from repro.launch.train import restore_runtime_state
from repro.models.model import init_params
from repro.optim.optimizers import adamw
from repro.train import (
    DeftRuntime,
    assign_buckets,
    build_bucket_layout,
    build_leaf_time_model,
    leaf_bucket_times,
)

WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
B, S = 4, 32


# ---------------------------------------------------------------------------
# HealthMonitor policies
# ---------------------------------------------------------------------------
def test_dead_detection_by_heartbeat_timeout():
    mon = HealthMonitor(4)
    events = []
    for step in range(30):
        walls = [1.0, None if step >= 6 else 1.0, 1.0, 1.0]
        events += mon.observe(step, walls)
    dead = [e for e in events if e.kind == "dead"]
    assert [e.shard for e in dead] == [1], events
    assert mon.status[1] == "dead"
    assert mon.alive_shards() == [0, 2, 3]
    # terminal until reset: no duplicate events on continued silence
    assert len(dead) == 1
    mon.reset(2)
    assert mon.status == ["healthy", "healthy"]
    assert mon.events, "the event trail survives a reset"


def test_straggler_detection_and_recovery():
    mon = HealthMonitor(4)
    events = []
    for step in range(40):
        slow = 3.0 if 5 <= step < 15 else 1.0
        events += mon.observe(step, [1.0, 1.0, slow, 1.0])
    kinds = [(e.kind, e.shard) for e in events]
    assert ("straggler", 2) in kinds
    assert ("recovered", 2) in kinds
    assert kinds.index(("straggler", 2)) < kinds.index(("recovered", 2))
    assert mon.status[2] == "healthy"
    assert not any(e.kind == "dead" for e in events)


def test_uniform_slowdown_is_bandwidth_not_straggler():
    """Every shard slowing together is drift for the adaptive replanner
    (informational 'bandwidth'), never a straggler/dead verdict."""
    mon = HealthMonitor(4)
    events = []
    for step in range(30):
        wall = 3.0 if step >= 10 else 1.0
        coll = 0.6 if step >= 10 else 0.2
        events += mon.observe(step, [wall] * 4, [coll] * 4)
    assert all(e.kind == "bandwidth" for e in events), events
    assert len([e for e in events if e.kind == "bandwidth"]) == 1
    assert mon.alive_shards() == [0, 1, 2, 3]


def test_silent_after_reset_is_declared_dead():
    """reset() stamps every shard's liveness at the reset instant (clock
    continuous), so a shard that never heartbeats after a mesh change —
    e.g. a returnee that fails to actually come back — accumulates
    silence from the reset and is declared dead, not skipped forever."""
    mon = HealthMonitor(4)
    for step in range(8):
        mon.observe(step, [1.0] * 4)
    mon.reset(4)
    events = []
    for step in range(8, 48):
        events += mon.observe(step, [1.0, 1.0, 1.0, None])
    dead = [e for e in events if e.kind == "dead"]
    assert [e.shard for e in dead] == [3], events
    assert mon.alive_shards() == [0, 1, 2]


def test_preemption_notice_is_immediate_and_single():
    mon = HealthMonitor(2)
    ev = mon.notice_preemption(7, 1, detail="spot reclaim")
    assert ev is not None and ev.kind == "preemption" and ev.shard == 1
    assert mon.status[1] == "preempted"
    assert mon.notice_preemption(8, 1) is None   # already terminal
    assert mon.alive_shards() == [0]


# ---------------------------------------------------------------------------
# FaultScenario determinism
# ---------------------------------------------------------------------------
def test_fault_scenario_replays_deterministically():
    scen = FaultScenario(4, (
        DeviceDrop(5, (3,)),
        StragglerSlowdown(2, 1, 2.5, end_step=8),
        BandwidthCollapse(6, 3.0, end_step=10),
        CapacityReturn(12, (3,)),
    ))
    for step in (0, 2, 5, 6, 9, 12, 20):
        assert scen.observe(step, 1.0, 0.2) == scen.observe(step, 1.0, 0.2)
    assert scen.dead_at(4) == frozenset()
    assert scen.dead_at(5) == frozenset({3})
    assert scen.dead_at(12) == frozenset()       # capacity returned
    obs = scen.observe(3, 1.0)
    assert obs.walls[1] == pytest.approx(2.5)    # straggler multiplies
    assert obs.walls[0] == pytest.approx(1.0)
    obs = scen.observe(7, 1.0, 0.2)
    assert obs.walls[3] is None                  # dead: missed heartbeat
    assert obs.comm_scale == 3.0
    # the collective excursion rides every live shard's critical path
    assert obs.walls[0] == pytest.approx(1.0 + 0.2 * 2.0)
    assert scen.observe(12, 1.0).returned == (3,)


# ---------------------------------------------------------------------------
# Accumulator-row folding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_old,n_new", [(4, 2), (4, 3), (2, 4), (3, 4)])
def test_fold_accum_rows_preserves_global_mean(n_old, n_new):
    """psum(rows)/n — the global-mean gradient the delayed update
    consumes — survives any width change under a constant global batch."""
    rows = jnp.asarray(
        np.random.RandomState(0).randn(n_old, 33).astype(np.float32)
    )
    out = fold_accum_rows(rows, n_new)
    assert out.shape == (n_new, 33)
    np.testing.assert_allclose(
        np.asarray(out).sum(0) / n_new,
        np.asarray(rows).sum(0) / n_old,
        rtol=0, atol=1e-6,
    )
    assert fold_accum_rows(rows, n_old) is rows   # width unchanged: no-op


# ---------------------------------------------------------------------------
# ElasticController degradation ladder
# ---------------------------------------------------------------------------
def _tiny_cfg():
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )


def _controller(cfg, params, pe=20_000):
    bo, nb = assign_buckets(params, cfg, partition_elems=pe)

    def model_for(width):
        m = build_leaf_time_model(
            params, cfg, HardwareModel(dp_degree=width), S,
            max(B // width, 1),
        )
        return m.with_coverage_rate(bo, nb, 1.8)

    return ElasticController(model_for, bo, nb, walk=WALK), bo, nb


def test_controller_degradation_ladder():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctrl, bo, nb = _controller(cfg, params)

    down = ctrl.propose(10, 2, "dead")
    assert down.action == "scale-down" and down.sharded
    assert down.schedule is not None and down.verdict is not None
    assert down.n_shards == 2 and down.plan_s > 0
    assert down.bucket_of == bo and down.n_buckets == nb

    repl = ctrl.propose(11, 1, "dead")
    assert repl.action == "fallback-replicated" and not repl.sharded
    assert repl.schedule is not None

    halt = ctrl.propose(12, 0, "preemption")
    assert halt.action == "checkpoint-halt" and halt.n_shards == 0

    up = ctrl.propose(13, 4, "scale-up")
    assert up.action == "scale-up" and up.sharded

    ctrl.adopt(down)
    assert ctrl.scheduler_cfg == down.scheduler_cfg
    assert len(ctrl.plans) == 4


# ---------------------------------------------------------------------------
# Coordinator armed-plan invariants (planning only, no migration executes)
# ---------------------------------------------------------------------------
class _StubMesh:
    axis_names = ("data", "model")

    def __init__(self, n):
        self.devices = np.empty((n, 1), dtype=object)


class _StubRuntime:
    """Planning-only stand-in: phase_in_cycle never hits a boundary, so
    armed plans stay armed and no real mesh/state is needed."""

    flat_state = True

    def __init__(self, n):
        self.mesh = _StubMesh(n)

    def phase_in_cycle(self, i):
        return 1


def _stub_coord(n=4, hc=None):
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctrl, _, _ = _controller(cfg, params)
    return ElasticCoordinator(
        _StubRuntime(n), ctrl, HealthMonitor(n, hc), params_abs=params,
    )


def test_cascading_faults_never_resurrect_lost_shards():
    """A second fault while a removal is armed extends the plan from the
    surviving set — the first casualty (in the spare pool, still in
    members) must not reappear in the pending membership."""
    coord = _stub_coord()
    coord.notice_preemption(5, [3])
    assert coord._pending is not None and coord._pending.n_shards == 3
    assert coord._pending_members == [0, 1, 2]
    coord.notice_preemption(6, [2])
    assert coord._pending.n_shards == 2
    assert coord._pending_members == [0, 1]
    assert set(coord._pending_members).isdisjoint(coord.spares)
    assert sorted(coord.spares) == [2, 3]
    # re-noticing an already-planned-out shard changes nothing
    coord.notice_preemption(7, [3])
    assert coord._pending.n_shards == 2 and sorted(coord.spares) == [2, 3]


def test_capacity_return_merges_with_armed_fault_plan():
    """Capacity returning for one armed-out shard cancels just that
    removal; the other fault's removal stays armed — no duplicate
    members, no clobbered fault plan."""
    coord = _stub_coord()
    coord.notice_preemption(5, [3])
    coord.notice_preemption(6, [2])
    coord.notice_capacity(7, [3])          # 3 restored before execution
    assert coord._pending is not None
    assert coord._pending_members == [0, 1, 3]
    assert len(set(coord._pending_members)) == 3
    assert coord._pending.n_shards == 3
    assert coord._pending.trigger == "preemption"  # 2's removal remains
    assert coord.spares == [2]
    coord.notice_capacity(8, [2])          # full cancellation: disarm
    assert coord._pending is None and coord.spares == []
    assert coord._pending_members == [] and coord._returning == []
    assert coord.members == [0, 1, 2, 3]


def test_straggler_recovery_cancels_and_cleans_spares():
    """A straggler recovering before its armed removal executes is fully
    restored: out of the spare pool, plan disarmed, no stale reason —
    and a later capacity notice naming it is a no-op, not a
    duplicate-member scale-up plan."""
    hc = HealthConfig(warmup_steps=1, straggler_ratio=1.5,
                      straggler_patience=2, recovered_ratio=1.3,
                      recovered_patience=2)
    coord = _stub_coord(hc=hc)
    step = 0
    while coord._pending is None:
        coord.observe(step, [1.0, 1.0, 4.0, 1.0])
        step += 1
        assert step < 20, "straggler never detected"
    assert coord._pending.trigger == "straggler"
    assert coord.spares == [2] and coord._out_reason == {2: "straggler"}
    while coord._pending is not None:
        coord.observe(step, [1.0] * 4)
        step += 1
        assert step < 60, "straggler never recovered"
    assert coord.spares == [] and coord._out_reason == {}
    assert coord._pending_members == []
    assert coord.stats()["spares"] == ()
    coord.notice_capacity(step, [2])
    assert coord._pending is None


# ---------------------------------------------------------------------------
# Atomic checkpoints: kill-mid-write never poisons a resume
# ---------------------------------------------------------------------------
def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"w": r.randn(5, 3).astype(np.float32),
            "b": r.randn(7).astype(np.float32)}


def test_truncated_newest_checkpoint_resume_picks_previous(tmp_path):
    d = str(tmp_path)
    t5, t9 = _tree(5), _tree(9)
    save(d, 5, t5)
    save(d, 9, t9)
    assert latest_step(d) == 9
    truncate_checkpoint(d, 9)                 # the KillMidCheckpoint damage
    assert not is_complete(d, 9)
    assert valid_steps(d) == [5]
    assert latest_step(d) == 5
    got = restore(d, 5, t5)
    np.testing.assert_array_equal(np.asarray(got["w"]), t5["w"])
    # a fresh save of the damaged step fully recovers it
    save(d, 9, t9)
    assert latest_step(d) == 9


def test_missing_sidecar_means_incomplete(tmp_path):
    d = str(tmp_path)
    save(d, 3, _tree())
    os.remove(os.path.join(d, "ckpt_00000003.json"))
    assert not is_complete(d, 3)
    assert latest_step(d) is None
    # no staging leftovers either way
    assert not [f for f in os.listdir(d) if f.startswith(".ckpt_")]


# ---------------------------------------------------------------------------
# Runtime-level paths (single device)
# ---------------------------------------------------------------------------
def _plan(cfg, params, partition_elems, cr=1.8):
    bucket_of, nb = assign_buckets(params, cfg,
                                   partition_elems=partition_elems)
    t = leaf_bucket_times(params, cfg, bucket_of, nb,
                          HardwareModel(dp_degree=2), S, B)
    scale = cr * (t.fwd_total + t.bwd_total) / t.comm_total
    t = BucketTimes(t.fwd, t.bwd, tuple(c * scale for c in t.comm))
    sched, _, scfg, _ = feedback_solve(t, WALK)
    return bucket_of, nb, t, sched, scfg


def _runtime(cfg, mesh, pe=20_000, cr=1.8, fsdp=False):
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    bo, nb, _, sched, scfg = _plan(cfg, params, pe, cr=cr)
    layout = build_bucket_layout(params, bo, nb, shard_count=1)
    rt = DeftRuntime(cfg, opt, sched, layout, mesh, fsdp=fsdp)
    return rt, rt.init_state(key), params


def test_prepare_swap_compile_failure_retries_then_succeeds(single_mesh):
    """An injected background compile exception surfaces in swap_log and
    the retry loop recovers — the staged swap is never silently lost."""
    cfg = _tiny_cfg()
    rt, state, params = _runtime(cfg, single_mesh)
    _, _, _, sched_b, _ = _plan(cfg, params, 20_000, cr=3.5)
    assert schedule_digest(sched_b) != schedule_digest(rt.schedule)
    orig = rt._compile_entries
    calls = {"n": 0}

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("injected compile failure")
        return orig(*a, **k)

    rt._compile_entries = flaky
    with jax.set_mesh(single_mesh):
        for step in range(2):
            state, _ = rt.step(step, state, make_batch(cfg, 0, step, B, S))
        rt.prepare_swap(sched_b, state, make_batch(cfg, 0, 0, B, S),
                        background=True, retries=3, retry_backoff_s=0.01)
        assert rt.wait_swap_ready(timeout=300)
        del rt.__dict__["_compile_entries"]
        fails = [e for e in rt.swap_log
                 if e.get("event") == "swap-compile-failed"]
        assert len(fails) == 2 and all(e["retrying"] for e in fails)
        assert rt.swap_failures == 2
        assert "injected compile failure" in rt.last_swap_error
        # the retried swap installs at the next cycle boundary
        for step in range(2, 2 * rt.period + 2):
            state, m = rt.step(step, state, make_batch(cfg, 0, step, B, S))
    assert rt.hot_swaps == 1 and rt.schedule == sched_b
    assert bool(jnp.isfinite(m["loss"]))


def test_prepare_swap_failure_exhausted_keeps_old_plan(single_mesh):
    """Retry budget exhausted: the runtime keeps stepping the old plan,
    the failure is on record, and a subsequent replan succeeds."""
    cfg = _tiny_cfg()
    rt, state, params = _runtime(cfg, single_mesh)
    _, _, _, sched_b, _ = _plan(cfg, params, 20_000, cr=3.5)
    old_period = rt.period

    def always_fail(*a, **k):
        raise RuntimeError("injected compile failure")

    rt._compile_entries = always_fail
    with jax.set_mesh(single_mesh):
        info = rt.prepare_swap(sched_b, state, make_batch(cfg, 0, 0, B, S),
                               background=True, retries=1,
                               retry_backoff_s=0.01)
        rt.wait_swap_ready(timeout=300)
        assert not rt.swap_ready()
        fails = [e for e in rt.swap_log
                 if e.get("event") == "swap-compile-failed"]
        assert len(fails) == 2                  # first try + one retry
        assert not fails[-1]["retrying"]
        assert "injected compile failure" in rt.last_swap_error
        # the abandonment closes the books: callers reading `info` can
        # tell an abandoned build from one that never started
        assert info["abandoned"] is True
        assert info["compile_attempts"] == 2 and info["compile_s"] > 0
        ab = [e for e in rt.swap_log if e.get("event") == "swap-abandoned"]
        assert len(ab) == 1 and ab[0]["attempts"] == 2
        assert ab[0]["elapsed_s"] > 0 and not ab[0]["superseded"]
        # old plan keeps stepping across what would have been the boundary
        for step in range(2 * old_period + 1):
            state, m = rt.step(step, state, make_batch(cfg, 0, step, B, S))
        assert rt.hot_swaps == 0 and rt.period == old_period
        assert bool(jnp.isfinite(m["loss"]))
        # the world recovers: the next replan compiles and installs
        del rt.__dict__["_compile_entries"]
        step0 = 2 * old_period + 1
        rt.prepare_swap(sched_b, state, make_batch(cfg, 0, 0, B, S),
                        background=True)
        assert rt.wait_swap_ready(timeout=300)
        for step in range(step0, step0 + old_period + 1):
            state, m = rt.step(step, state, make_batch(cfg, 0, step, B, S))
    assert rt.hot_swaps == 1 and rt.schedule == sched_b


def test_resume_digest_mismatch_restarts_cycle(single_mesh, tmp_path):
    """A checkpoint whose sidecar names a different schedule digest
    restores at cycle start (satellite: resume hardening) — the saved
    mid-cycle position is meaningless under the running schedule."""
    d = str(tmp_path)
    cfg = _tiny_cfg()
    rt_a, state, params = _runtime(cfg, single_mesh, cr=5.0)  # period 5
    k = rt_a.period + 2                         # mid-cycle save point
    with jax.set_mesh(single_mesh):
        for step in range(k):
            state, _ = rt_a.step(step, state, make_batch(cfg, 0, step, B, S))
        save(d, k, rt_a.state_to_tree(state))
        save_layout_descriptor(
            d, k, rt_a.layout, next_phase=rt_a.phase_in_cycle(k),
            digest=schedule_digest(rt_a.schedule),
        )
        assert rt_a.phase_in_cycle(k) == 2

        # same layout, different schedule -> digest mismatch
        rt_b, _, _ = _runtime(cfg, single_mesh, cr=3.5)  # period 2
        assert rt_b.layout == rt_a.layout
        assert schedule_digest(rt_b.schedule) != schedule_digest(rt_a.schedule)
        assert k % rt_b.period != 0     # the assertion below is non-trivial
        got, start = restore_runtime_state(rt_b, d, params)
        assert start == k and got is not None
        assert rt_b.phase_in_cycle(k) == 0      # cycle-start fallback
        state_b, m = rt_b.step(k, got, make_batch(cfg, 0, k, B, S))
        assert bool(jnp.isfinite(m["loss"]))

        # control: the identical schedule resumes mid-cycle
        rt_c, _, _ = _runtime(cfg, single_mesh, cr=5.0)
        _, start = restore_runtime_state(rt_c, d, params)
        assert start == k and rt_c.phase_in_cycle(k) == 2


def test_resume_skips_torn_step_falls_back(single_mesh, tmp_path):
    """restore_runtime_state walks valid steps newest-first: a torn
    newest checkpoint resumes from the previous complete one."""
    d = str(tmp_path)
    cfg = _tiny_cfg()
    rt, state, params = _runtime(cfg, single_mesh)
    with jax.set_mesh(single_mesh):
        for step in range(3):
            state, _ = rt.step(step, state, make_batch(cfg, 0, step, B, S))
            save(d, step + 1, rt.state_to_tree(state))
            save_layout_descriptor(
                d, step + 1, rt.layout,
                next_phase=rt.phase_in_cycle(step + 1),
                digest=schedule_digest(rt.schedule),
            )
        truncate_checkpoint(d, 3)
        rt2, _, _ = _runtime(cfg, single_mesh)
        got, start = restore_runtime_state(rt2, d, params)
    assert start == 2 and got is not None


def test_engine_fallback_migration_matches_reference(single_mesh):
    """Sharded -> replicated flat engine fallback via migrate_state: the
    degraded-mode trajectory matches a reference runtime compiled
    directly for the replicated engine from the same state."""
    cfg = _tiny_cfg()
    rt_a, state, params = _runtime(cfg, single_mesh, cr=3.5, fsdp=True)
    k = rt_a.period * 2          # a cycle boundary (period 2 at cr=3.5)
    with jax.set_mesh(single_mesh):
        for step in range(k):
            state, _ = rt_a.step(step, state, make_batch(cfg, 0, step, B, S))
        snap = jax.tree.map(np.array, rt_a.state_to_tree(state))

        rt_b = rt_a.spawn(fsdp=False)
        assert not rt_b.fsdp and rt_b.layout == rt_a.layout
        state_b = migrate_state(rt_a, rt_b, state)
        rt_b.reset_cycle(k)
        losses = []
        for step in range(k, 2 * k):
            state_b, m = rt_b.step(step, state_b,
                                   make_batch(cfg, 0, step, B, S))
            losses.append(float(m["loss"]))

        rt_ref = DeftRuntime(cfg, rt_a.opt_spec, rt_a.schedule, rt_a.layout,
                             single_mesh, fsdp=False)
        state_r = rt_ref.tree_to_state(jax.tree.map(jnp.asarray, snap))
        rt_ref.reset_cycle(k)
        losses_ref = []
        for step in range(k, 2 * k):
            state_r, m = rt_ref.step(step, state_r,
                                     make_batch(cfg, 0, step, B, S))
            losses_ref.append(float(m["loss"]))
    np.testing.assert_allclose(losses, losses_ref, rtol=0, atol=1e-5)
    for a, b in zip(jax.tree.leaves(rt_b.params_tree(state_b)),
                    jax.tree.leaves(rt_ref.params_tree(state_r))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)


def test_coordinator_halt_emergency_checkpoint_and_resume(
        single_mesh, tmp_path):
    """The ladder's bottom rung: every shard preempted -> emergency
    checkpoint + ElasticHalt; a fresh runtime resumes from it."""
    d = str(tmp_path)
    cfg = _tiny_cfg()
    rt, state, params = _runtime(cfg, single_mesh)
    ctrl, _, _ = _controller(cfg, params)
    coord = ElasticCoordinator(
        rt, ctrl, HealthMonitor(1), params_abs=params, checkpoint_dir=d,
    )
    with jax.set_mesh(single_mesh):
        for step in range(3):
            state, _ = coord.step(step, state,
                                  make_batch(cfg, 0, step, B, S))
        ref = jax.tree.map(np.array, rt.state_to_tree(state))
        events = coord.notice_preemption(3, [0])
        assert [e.kind for e in events] == ["preemption"]
        with pytest.raises(ElasticHalt) as err:
            coord.step(3, state, make_batch(cfg, 0, 3, B, S))
        assert err.value.step == 3 and err.value.checkpoint_path
        assert coord.log[-1]["action"] == "checkpoint-halt"

        assert latest_step(d) == 3
        rt2, _, _ = _runtime(cfg, single_mesh)
        got, start = restore_runtime_state(rt2, d, params)
    assert start == 3
    for a, b in zip(jax.tree.leaves(rt2.state_to_tree(got)),
                    jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Chaos: end-to-end recovery on forced devices (subprocess)
# ---------------------------------------------------------------------------
_COMMON = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import feedback_solve
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.data.pipeline import batch_spec, make_batch
from repro.elastic import (CapacityReturn, DeviceDrop, ElasticController,
                           ElasticCoordinator, FaultScenario, HealthConfig,
                           HealthMonitor, StragglerSlowdown, migrate_state)
from repro.launch.mesh import make_debug_mesh, make_elastic_mesh
from repro.models.model import init_params
from repro.optim.optimizers import adamw
from repro.train import (DeftRuntime, assign_buckets, build_bucket_layout,
                         build_leaf_time_model, leaf_bucket_times)

S = 32
WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)

def tiny_cfg():
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512)

def setup(B):
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    bo, nb = assign_buckets(params, cfg, partition_elems=20_000)
    def model_for(width):
        m = build_leaf_time_model(params, cfg,
                                  HardwareModel(dp_degree=width), S,
                                  max(B // width, 1))
        return m.with_coverage_rate(bo, nb, 1.8)
    times4 = model_for(4).bucket_times(bo, nb)
    sched, verdict, scfg, _ = feedback_solve(times4, WALK)
    mesh4 = make_debug_mesh(data=4, model=1)
    layout4 = build_bucket_layout(params, bo, nb, shard_count=4)
    rt = DeftRuntime(cfg, adamw(1e-3), sched, layout4, mesh4, fsdp=True)
    ctrl = ElasticController(model_for, bo, nb, walk=WALK,
                             scheduler_cfg=scfg)
    mon = HealthMonitor(4, HealthConfig(warmup_steps=1, timeout_factor=3.0,
                                        straggler_ratio=1.5,
                                        straggler_patience=2))
    coord = ElasticCoordinator(rt, ctrl, mon, params_abs=params,
                               batch_spec=batch_spec(cfg, B, S))
    return cfg, params, rt, coord, sched, mesh4
"""

_DROP_SCRIPT = _COMMON + r"""
B = 8
cfg, params, rt, coord, sched, mesh4 = setup(B)
DROP = 4
scen = FaultScenario(4, (DeviceDrop(DROP, (2, 3)),))
N1 = DROP + 4 * sched.period

with jax.set_mesh(mesh4):
    state = rt.init_state(jax.random.PRNGKey(0))
    losses, snap_tree, m_step = [], None, None
    for step in range(N1):
        state = coord.maybe_migrate(step, state)
        if coord.runtime is not rt and snap_tree is None:
            m_step = step    # post-migration, pre-step: the repacked state
            snap_tree = jax.tree.map(np.array,
                                     coord.runtime.state_to_tree(state))
        state, m = coord.runtime.step(step, state,
                                      make_batch(cfg, 0, step, B, S))
        losses.append(float(m["loss"]))
        coord.observe(step, list(scen.observe(step, 1.0).walls))
    assert m_step is not None, "scale-down never executed"
    mig = coord.log[0]
    assert mig["action"] == "scale-down" and mig["trigger"] == "dead"
    assert (mig["old_shards"], mig["new_shards"]) == (4, 2)
    assert mig["preserver_ok"], mig
    assert coord.members == [0, 1] and sorted(coord.spares) == [2, 3]
    assert coord.runtime.phase_in_cycle(m_step) == 0
    det = mig["detected_step"]
    assert DROP < det <= m_step, (DROP, det, m_step)
    print("ELASTIC_DOWN_OK", m_step, det, flush=True)

    # ---- reference: from-scratch 2-shard run from the repacked state
    plan = [p for p in coord.controller.plans if p.action == "scale-down"][-1]
    mesh2 = make_elastic_mesh([tuple(mesh4.devices[0, :]),
                               tuple(mesh4.devices[1, :])])
    layout2 = build_bucket_layout(params, plan.bucket_of, plan.n_buckets,
                                  shard_count=2)
    rt_ref = DeftRuntime(cfg, adamw(1e-3), plan.schedule, layout2, mesh2,
                         fsdp=True)
    with jax.set_mesh(mesh2):
        state_r = rt_ref.tree_to_state(jax.tree.map(jnp.asarray, snap_tree))
        rt_ref.reset_cycle(m_step)
        losses_ref = []
        for step in range(m_step, N1):
            state_r, m = rt_ref.step(step, state_r,
                                     make_batch(cfg, 0, step, B, S))
            losses_ref.append(float(m["loss"]))
    np.testing.assert_allclose(losses[m_step:], losses_ref,
                               rtol=0, atol=1e-5)
    for a, b in zip(
            jax.tree.leaves(coord.runtime.params_tree(state)),
            jax.tree.leaves(rt_ref.params_tree(state_r))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)
    print("ELASTIC_REF_MATCH", flush=True)

    # ---- capacity returns: symmetric 2 -> 4 scale-up, zero restart
    coord.notice_capacity(N1, [2, 3])
    N2 = N1 + 3 * coord.runtime.period
    for step in range(N1, N2):
        state = coord.maybe_migrate(step, state)
        state, m = coord.runtime.step(step, state,
                                      make_batch(cfg, 0, step, B, S))
        coord.observe(step, [1.0] * 4)
    up = coord.log[-1]
    assert up["action"] == "scale-up"
    assert (up["old_shards"], up["new_shards"]) == (2, 4)
    assert coord.members == [0, 1, 2, 3] and coord.spares == []
    assert np.isfinite(float(m["loss"]))
    print("ELASTIC_UP_OK", flush=True)

    # ---- A -> B -> A round trip through migrate_state/repack_state:
    # params + optimizer state are bitwise; the folded accumulator rows
    # preserve the global-mean gradient (DESIGN.md S10 fold semantics)
    rt4 = coord.runtime
    orig = jax.tree.map(np.array, state)
    rt2 = rt4.spawn(mesh=mesh2, schedule=plan.schedule, layout=layout2,
                    fsdp=True)
    rt4b = rt2.spawn(mesh=rt4.mesh, schedule=rt4.schedule, layout=rt4.layout,
                     fsdp=True)
    down = migrate_state(rt4, rt2, jax.tree.map(jnp.asarray, orig))
    back = migrate_state(rt2, rt4b, down)
    for key in back:
        if key in ("cur", "fut"):
            for got, want in zip(back[key], orig[key]):
                np.testing.assert_allclose(
                    np.asarray(got).sum(0), np.asarray(want).sum(0),
                    rtol=0, atol=2e-5)
        elif key != "pgather":   # derived cache, recreated per repack
            for got, want in zip(jax.tree.leaves(back[key]),
                                 jax.tree.leaves(orig[key])):
                assert np.array_equal(np.asarray(got), np.asarray(want)), key
    print("ELASTIC_ROUNDTRIP_OK", flush=True)
"""

_CASCADE_SCRIPT = _COMMON + r"""
B = 8
cfg, params, rt, coord, sched, mesh4 = setup(B)

with jax.set_mesh(mesh4):
    state = rt.init_state(jax.random.PRNGKey(0))
    for step in range(2):
        state = coord.maybe_migrate(step, state)
        state, m = coord.runtime.step(step, state,
                                      make_batch(cfg, 0, step, B, S))
        coord.observe(step, [1.0] * 4)

    # two faults in the same cycle window: the second plan must extend
    # the armed removal from the surviving set, never re-seat the first
    # casualty on a dead device
    coord.notice_preemption(2, [3])
    assert coord._pending is not None and coord._pending.n_shards == 3
    coord.notice_preemption(2, [2])
    assert coord._pending.n_shards == 2
    assert coord._pending_members == [0, 1]
    assert set(coord._pending_members).isdisjoint(coord.spares)

    N = 2 + 3 * sched.period
    for step in range(2, N):
        state = coord.maybe_migrate(step, state)
        state, m = coord.runtime.step(step, state,
                                      make_batch(cfg, 0, step, B, S))
        coord.observe(step, [1.0] * 4)
    downs = [e for e in coord.log if e["action"] == "scale-down"]
    assert len(downs) == 1, coord.log       # ONE migration covers both
    assert downs[0]["trigger"] == "preemption"
    assert (downs[0]["old_shards"], downs[0]["new_shards"]) == (4, 2)
    assert coord.members == [0, 1] and sorted(coord.spares) == [2, 3]
    # the survivor mesh is rows 0,1 of the origin mesh — no dead devices
    assert (coord.runtime.mesh.devices == mesh4.devices[:2, :]).all()
    assert np.isfinite(float(m["loss"]))
    print("CASCADE_OK", flush=True)
"""

_STRAGGLER_SCRIPT = _COMMON + r"""
B = 12    # divisible by 4 and by the surviving 3 shards
cfg, params, rt, coord, sched, mesh4 = setup(B)
ONSET = 3
scen = FaultScenario(4, (StragglerSlowdown(ONSET, 1, 4.0),))
N = ONSET + 4 * sched.period

with jax.set_mesh(mesh4):
    state = rt.init_state(jax.random.PRNGKey(0))
    for step in range(N):
        state = coord.maybe_migrate(step, state)
        state, m = coord.runtime.step(step, state,
                                      make_batch(cfg, 0, step, B, S))
        coord.observe(step, list(scen.observe(step, 1.0).walls))
    assert coord.log, "straggler removal never executed"
    mig = coord.log[0]
    assert mig["action"] == "scale-down" and mig["trigger"] == "straggler"
    assert (mig["old_shards"], mig["new_shards"]) == (4, 3)
    assert coord.members == [0, 2, 3] and coord.spares == [1]
    assert coord.runtime.accum_devices == 3
    assert np.isfinite(float(m["loss"]))
    print("STRAGGLER_OK", flush=True)
"""


def _run_chaos(tmp_path, script):
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    path = tmp_path / "run.py"
    path.write_text(script)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, str(path), src],
        capture_output=True, text=True, timeout=900, env=env,
    )


@pytest.mark.slow
@pytest.mark.chaos
def test_chaos_device_drop_scale_down_up_roundtrip(tmp_path):
    """The acceptance scenario: drop 2 of 4 shards mid-run -> detection
    -> Preserver-gated 4->2 scale-down repack at a cycle boundary with
    zero restart; the post-fault trajectory matches a from-scratch
    2-shard run from the repacked state within 1e-5; capacity returns
    and the mesh scales back 2->4; A->B->A round-trips params/opt
    bitwise with the accumulator global mean preserved."""
    out = _run_chaos(tmp_path, _DROP_SCRIPT)
    assert out.returncode == 0, out.stderr[-4000:]
    for marker in ("ELASTIC_DOWN_OK", "ELASTIC_REF_MATCH",
                   "ELASTIC_UP_OK", "ELASTIC_ROUNDTRIP_OK"):
        assert marker in out.stdout, (marker, out.stdout[-2000:])


@pytest.mark.slow
@pytest.mark.chaos
def test_chaos_cascading_faults_one_scale_down(tmp_path):
    """Two preemptions in the same cycle window fold into ONE armed
    4->2 scale-down that excludes both casualties; the first lost shard
    is never resurrected onto the survivor mesh."""
    out = _run_chaos(tmp_path, _CASCADE_SCRIPT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "CASCADE_OK" in out.stdout, out.stdout[-2000:]


@pytest.mark.slow
@pytest.mark.chaos
def test_chaos_straggler_scale_down_4_to_3(tmp_path):
    """A 4x straggler is planned out of the mesh: 4->3 scale-down (a
    non-power-of-two survivor count) and training continues."""
    out = _run_chaos(tmp_path, _STRAGGLER_SCRIPT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "STRAGGLER_OK" in out.stdout, out.stdout[-2000:]
