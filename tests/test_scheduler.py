"""Algorithm 2 state-machine invariants (paper §III.B, Fig. 4).

The key system invariant DeFT must preserve: every gradient generation is
synchronized EXACTLY ONCE per bucket before the parameter update that
consumes it, and no gradient is dropped.
"""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bucket import BucketTimes
from repro.core.scheduler import (
    DeftScheduler,
    SchedulerConfig,
    extract_schedule,
)


def make_times(fwd, bwd, comm):
    return BucketTimes(tuple(fwd), tuple(bwd), tuple(comm))


times_strategy = st.integers(min_value=2, max_value=10).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(0.001, 0.1), min_size=n, max_size=n),
        st.lists(st.floats(0.001, 0.2), min_size=n, max_size=n),
        st.lists(st.floats(0.001, 0.5), min_size=n, max_size=n),
    )
)


@given(times_strategy, st.booleans())
@settings(max_examples=30, deadline=None)
def test_every_generation_synced_once_before_update(t, hetero):
    times = make_times(*t)
    sched = DeftScheduler(times, SchedulerConfig(heterogeneous=hetero))
    plans = sched.run(48)
    n = times.n
    synced = {}          # (bucket, origin) -> times synced
    updated_origins = set()
    for plan in plans:
        for task in plan.synced:
            for o in task.origins:
                key = (task.bucket, o)
                synced[key] = synced.get(key, 0) + 1
                assert o not in updated_origins, (
                    "bucket synced after its origin was already applied"
                )
        if plan.update:
            for o in plan.update_origins:
                for b in range(n):
                    assert synced.get((b, o), 0) == 1, (
                        f"update consumed origin {o} but bucket {b} was "
                        f"synced {synced.get((b, o), 0)} times"
                    )
                updated_origins.add(o)
    # no double sync anywhere
    assert all(v == 1 for v in synced.values())


@given(times_strategy)
@settings(max_examples=30, deadline=None)
def test_no_origin_skipped(t):
    """Updates consume consecutive origins — no iteration's gradient is
    silently dropped."""
    times = make_times(*t)
    plans = DeftScheduler(times, SchedulerConfig()).run(64)
    applied = sorted(
        o for p in plans if p.update for o in p.update_origins
    )
    assert applied == sorted(set(applied))
    if applied:
        assert applied == list(range(applied[0], applied[-1] + 1))


@given(times_strategy)
@settings(max_examples=20, deadline=None)
def test_schedule_extraction_periodic(t):
    times = make_times(*t)
    plans = DeftScheduler(times, SchedulerConfig()).run(96)
    sched = extract_schedule(plans, times.n)
    assert 1 <= sched.period <= 80
    assert len(sched.phases) == sched.period
    assert sched.updates_per_period == sum(1 for p in sched.plans if p.update)
    # batch-size sequence accounts for every iteration of the period
    if sched.updates_per_period:
        assert sum(sched.batch_size_sequence) >= sched.period * 0 + \
            sched.updates_per_period


def test_low_cr_syncs_everything_each_iteration():
    """CR << 1: all buckets fit into backward+forward — DeFT degenerates to
    per-iteration sync with update every step (matching WFBP semantics)."""
    times = make_times([0.1] * 4, [0.2] * 4, [0.01] * 4)
    plans = DeftScheduler(times, SchedulerConfig()).run(16)
    steady = plans[4:]
    assert all(p.update for p in steady)
    assert all(len(p.synced) == 4 for p in steady)


def test_high_cr_reduces_update_frequency():
    """CR ~ 3: the schedule must merge generations (update freq < 1)."""
    times = make_times([0.02] * 5, [0.04] * 5, [0.36] * 5)
    plans = DeftScheduler(times, SchedulerConfig(heterogeneous=False)).run(64)
    sched = extract_schedule(plans, 5)
    assert sched.updates_per_period < sched.period
    # volume reduction: fewer bucket-instances synced than generated
    assert sched.comm_volume_fraction < 1.0
    # but at least one update happens per period (progress)
    assert sched.updates_per_period >= 1


def test_heterogeneous_increases_update_frequency():
    """Paper §III.C: the second link carries extra buckets, so update
    frequency with heterogeneous links >= without."""
    times = make_times([0.02] * 6, [0.04] * 6, [0.2] * 6)
    f = []
    for hetero in (False, True):
        plans = DeftScheduler(
            times, SchedulerConfig(heterogeneous=hetero)
        ).run(64)
        sched = extract_schedule(plans, 6)
        f.append(sched.update_frequency)
    assert f[1] >= f[0]


def test_capacity_factor_monotone():
    """Preserver feedback: larger knapsack capacity -> more syncs per
    iteration -> update frequency moves toward 1."""
    times = make_times([0.02] * 5, [0.04] * 5, [0.3] * 5)
    freqs = []
    for factor in (1.0, 2.0, 6.0):
        plans = DeftScheduler(
            times, SchedulerConfig(capacity_factor=factor)
        ).run(64)
        freqs.append(extract_schedule(plans, 5).update_frequency)
    assert freqs == sorted(freqs)
