"""Sharded flat-state engine (DESIGN.md §8, sharded layout): the
FSDP/RS path of DeftRuntime with params and optimizer moments resident
as 1/N shard spans of the flat bucket buffers.

Covers, tier-1 (single device):

* shard-aware ``BucketLayout`` construction (padding to
  ``shard_count * 128``, span math, runtime validation);
* the sharded ``apply_bucket_updates`` path reassembling BITWISE against
  the full-buffer apply (clip off AND clip on with an emulated
  shard-norm psum — the update math is identical, only the collective
  sum order can differ on a real mesh);
* per-shard segment-map slicing;
* the jaxpr op-count claim: the sharded update path is O(buckets), the
  ZeRO-style per-leaf update over the same shard-sized state O(leaves);
* bf16 compute against the f32 master (mixed-precision satellite).

The true multi-device end-to-end equivalence run (4 forced host
devices, secondary-synced bucket, donation, tree-RS reference on
jax >= 0.5) lives in the ``multidevice``-marked subprocess test at the
bottom — wired into CI via the multidevice job.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import make_batch
from repro.kernels.bucket_update import (
    apply_bucket_updates,
    build_segments,
    init_flat_opt_state,
)
from repro.optim.optimizers import adamw, sgd_momentum
from repro.train.bucketing import (
    PAD_MULTIPLE,
    build_bucket_layout,
    flatten_buckets,
)

N_SHARDS = 4


def _tree():
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (37, 9)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (13,)),
        "h": jax.random.normal(jax.random.fold_in(key, 2), (200,)),
        "u": jax.random.normal(jax.random.fold_in(key, 3), (5, 7, 3)),
    }


def _sharded_layout(params, n_shards=N_SHARDS):
    return build_bucket_layout(params, (0, 1, 1, 0), 2,
                               shard_count=n_shards)


# ---------------------------------------------------------------------------
# Shard-aware layout construction
# ---------------------------------------------------------------------------
def test_shard_layout_padding_and_span_math():
    params = _tree()
    lay = _sharded_layout(params)
    assert lay.shards == N_SHARDS
    unit = N_SHARDS * PAD_MULTIPLE
    for b in range(lay.n_buckets):
        assert lay.buf_sizes[b] % unit == 0
        assert lay.buf_sizes[b] >= lay.sizes[b]
        assert lay.buf_sizes[b] - lay.sizes[b] < unit       # minimal pad
        assert lay.shard_sizes[b] == lay.buf_sizes[b] // N_SHARDS
        assert lay.shard_sizes[b] % PAD_MULTIPLE == 0       # kernel operand
    # the replicated layout of the same tree is a prefix of the sharded
    # one: identical leaf offsets/sizes, only the allocation grows
    rep = build_bucket_layout(params, (0, 1, 1, 0), 2)
    assert rep.offsets == lay.offsets and rep.sizes == lay.sizes
    assert all(p >= r for p, r in zip(lay.buf_sizes, rep.buf_sizes))
    # flatten fills the longer allocation with zero tails
    flat = flatten_buckets(lay, jax.tree.leaves(params))
    for b, f in enumerate(flat):
        assert f.shape == (lay.buf_sizes[b],)
        assert not np.any(np.asarray(f[lay.sizes[b]:]))


def test_shard_layout_rejects_bad_counts():
    with pytest.raises(ValueError, match="shard_count"):
        build_bucket_layout(_tree(), (0, 1, 1, 0), 2, shard_count=0)


def test_runtime_rejects_mismatched_shard_layout(single_mesh):
    """A DeftRuntime(fsdp=True) over a layout whose shard count does not
    match the mesh 'data' axis must fail loudly at construction, not
    deep inside the first compile."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.train import DeftRuntime, init_train_state
    from test_train_steps import _schedule_for

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    probe = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=0.5)
    lay = build_bucket_layout(probe["params"], bucket_of, nb,
                              shard_count=2)   # mesh data axis is 1
    with pytest.raises(ValueError, match="shard_count"):
        DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True)


# ---------------------------------------------------------------------------
# Sharded update path: bitwise reassembly against the full-buffer apply
# ---------------------------------------------------------------------------
SPECS = [
    adamw(1e-2, grad_clip=0.0, weight_decay=0.01),
    adamw(5.0, weight_decay=0.01),        # lr irrelevant; clip ENGAGES
    sgd_momentum(3e-2, momentum=0.85, weight_decay=0.02, grad_clip=0.0),
    adamw(1e-2, grad_clip=0.0, weight_decay=0.1, decay_mask="matrix",
          ndim1_lr_scale=0.5),            # mixed buckets -> segment maps
]
SPEC_IDS = ["adamw-noclip", "adamw-clip", "sgd-noclip", "adamw-segmented"]


def _shard_state(layout, bufs, s):
    spans = layout.shard_sizes
    return tuple(x[s * spans[b]:(s + 1) * spans[b]]
                 for b, x in enumerate(bufs))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_sharded_apply_reassembles_bitwise(spec):
    """Each shard runs the fused kernels on its span (pre-masked tail,
    per-shard segment slices, emulated cross-shard norm psum); the
    concatenated result must equal the full-buffer apply bit-for-bit
    when clipping is off — the sharded engine changes residency, never
    update math.  With clipping ON the global norm is reduced
    shard-wise (different partial-sum grouping), so the clip factor can
    move by an ulp: tight tolerance there."""
    params = _tree()
    layout = _sharded_layout(params)
    grads = jax.tree.map(lambda p: p * 3.0, params)  # big: clip engages
    seg = build_segments(layout, spec)
    adam = spec.name == "adamw"

    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
    gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    full_p, full_o, _ = apply_bucket_updates(
        spec, seg, pbuf, gbuf, opt_f, grad_scale=0.25, impl="ref"
    )

    # the psum the RS body issues, emulated: sum of the per-shard
    # squared-norm contributions (identical partial sums, same order)
    if spec.grad_clip:
        shard_sq = []
        for s in range(N_SHARDS):
            g_s = _shard_state(layout, gbuf, s)
            sq = sum(
                jnp.sum(jnp.square(g * 0.25)) for g in g_s
            )
            shard_sq.append(sq)
        global_sq = jnp.sum(jnp.stack(shard_sq))
        norm_psum = lambda _t: global_sq
    else:
        norm_psum = None

    got_p, got_m, got_v = [], [], []
    for s in range(N_SHARDS):
        o_s = {"step": opt_f["step"],
               "m": _shard_state(layout, opt_f["m"], s)}
        if adam:
            o_s["v"] = _shard_state(layout, opt_f["v"], s)
        sp, so, _ = apply_bucket_updates(
            spec, seg,
            _shard_state(layout, pbuf, s),
            _shard_state(layout, gbuf, s),
            o_s, grad_scale=0.25, impl="ref",
            shard_id=jnp.int32(s), norm_psum=norm_psum,
        )
        got_p.append(sp)
        got_m.append(so["m"])
        if adam:
            got_v.append(so["v"])
        assert int(so["step"]) == 1

    exact = spec.grad_clip == 0.0

    def check(re, full, what):
        if exact:
            assert bool(jnp.array_equal(re, full)), what
        else:
            np.testing.assert_allclose(np.asarray(re), np.asarray(full),
                                       atol=1e-6, rtol=1e-6, err_msg=what)

    for b in range(layout.n_buckets):
        re_p = jnp.concatenate([got_p[s][b] for s in range(N_SHARDS)])
        check(re_p, full_p[b], f"params bucket {b}")
        re_m = jnp.concatenate([got_m[s][b] for s in range(N_SHARDS)])
        check(re_m, full_o["m"][b], f"m bucket {b}")
        if adam:
            re_v = jnp.concatenate([got_v[s][b] for s in range(N_SHARDS)])
            check(re_v, full_o["v"][b], f"v bucket {b}")
        # tails stay exactly zero without the kernels' static mask
        assert not np.any(np.asarray(re_p[layout.sizes[b]:]))


def test_sharded_apply_masks_hostile_gradient_tail():
    """NaN riding the padded tail of the LAST shard's gradient span must
    not leak: the pre-mask zeroes it before both the clip norm and the
    kernel (the sharded twin of test_tail_garbage_is_masked)."""
    spec = adamw(1e-2)                                  # clip on
    params = _tree()
    layout = _sharded_layout(params)
    seg = build_segments(layout, spec)
    gbuf = [g.at[layout.sizes[b]:].set(jnp.nan)
            for b, g in enumerate(flatten_buckets(
                layout, jax.tree.leaves(params)))]
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    s = N_SHARDS - 1                                    # tail shard
    o_s = {"step": opt_f["step"], "m": _shard_state(layout, opt_f["m"], s),
           "v": _shard_state(layout, opt_f["v"], s)}
    sp, _, _ = apply_bucket_updates(
        spec, seg, _shard_state(layout, pbuf, s),
        _shard_state(layout, gbuf, s), o_s,
        grad_scale=1.0, impl="ref", shard_id=jnp.int32(s),
        norm_psum=lambda t: t,
    )
    for b in range(layout.n_buckets):
        assert bool(jnp.all(jnp.isfinite(sp[b]))), f"bucket {b}"


@pytest.mark.parametrize("clip", [0.0, 1.0], ids=["noclip", "clip"])
def test_single_shard_apply_degrades_to_unsharded(clip):
    """layout.shards == 1 (1-device FSDP smoke runs): the sharded path's
    span IS the whole buffer, and passing shard_id=0 must reproduce the
    unsharded apply instead of rejecting the layout — bit-for-bit with
    clipping off; to an ulp with clipping on (the norm reduces over the
    masked whole buffer vs the valid slice: same values, different
    pairwise-sum grouping)."""
    spec = adamw(1e-2, weight_decay=0.01, grad_clip=clip)
    params = _tree()
    layout = build_bucket_layout(params, (0, 1, 1, 0), 2)   # shards == 1
    grads = jax.tree.map(lambda p: p * 3.0, params)
    seg = build_segments(layout, spec)
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
    gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    full_p, _, _ = apply_bucket_updates(spec, seg, pbuf, gbuf, opt_f,
                                        grad_scale=0.25, impl="ref")
    sh_p, _, _ = apply_bucket_updates(
        spec, seg, pbuf, gbuf, opt_f, grad_scale=0.25, impl="ref",
        shard_id=jnp.int32(0), norm_psum=lambda t: t,
    )
    for a, b in zip(sh_p, full_p):
        if clip == 0.0:
            assert bool(jnp.array_equal(a, b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-6)


def test_runtime_flat_fsdp_runs_on_single_device(single_mesh):
    """The launch path for an FSDP arch on a 1-device debug mesh:
    shard_count=1 layout + DeftRuntime(fsdp=True) must construct,
    compile and step (the degenerate sharded engine) — a regression
    here used to surface only deep inside the first phase trace.  Runs
    in bf16 to also cover the sharded mixed-precision path (spans cast
    down BEFORE the param all-gather), checked tight-tol against the
    replicated flat bf16 engine on the same mesh."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.train import DeftRuntime, init_train_state
    from test_train_steps import B, S, _schedule_for

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=0.5)
    layout = build_bucket_layout(probe["params"], bucket_of, nb,
                                 shard_count=1)
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh, fsdp=True,
                         compute_dtype=jnp.bfloat16)
        assert rt.flat_state and rt.stats()["sharded_state"]
        state = rt.init_state(key, dtype=jnp.bfloat16)
        rt_rep = DeftRuntime(cfg, opt, sched, layout, single_mesh,
                             compute_dtype=jnp.bfloat16)
        state_rep = rt_rep.init_state(key, dtype=jnp.bfloat16)
        for step in range(sched.period + 1):
            batch = make_batch(cfg, 0, step, B, S)
            state, m = rt.step(step, state, batch)
            state_rep, _ = rt_rep.step(step, state_rep, batch)
            assert bool(jnp.isfinite(m["loss"]))
        diff = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(rt.params_tree(state)),
                            jax.tree.leaves(rt_rep.params_tree(state_rep)))
        )
        # same bf16 forward (the pre-gather cast is elementwise), same
        # f32 master updates; only collective rounding can differ
        assert diff < 1e-5, diff


def test_sharded_apply_with_clip_requires_norm_psum():
    """A sharded update with grad_clip on and no cross-shard norm psum
    would clip every shard from 1/N of the gradient — it must fail
    loudly, not silently diverge params."""
    params = _tree()
    layout = _sharded_layout(params)
    spec = adamw(1e-2)                                  # clip on
    seg = build_segments(layout, spec)
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    p_s = _shard_state(layout, pbuf, 0)
    o_s = {"step": opt_f["step"], "m": _shard_state(layout, opt_f["m"], 0),
           "v": _shard_state(layout, opt_f["v"], 0)}
    with pytest.raises(ValueError, match="norm_psum"):
        apply_bucket_updates(spec, seg, p_s, p_s, o_s,
                             shard_id=jnp.int32(0))


# ---------------------------------------------------------------------------
# Per-shard segment maps
# ---------------------------------------------------------------------------
def test_element_hparams_shard_slices_consistently():
    params = _tree()
    layout = _sharded_layout(params)
    spec = adamw(1e-2, weight_decay=0.1, decay_mask="matrix",
                 ndim1_lr_scale=0.5)
    seg = build_segments(layout, spec)
    for b in range(layout.n_buckets):
        assert seg.uniform(b) is None                   # mixed buckets
        sc_full, wd_full = seg.element_hparams(b)
        span = layout.shard_sizes[b]
        for s in range(N_SHARDS):
            sc, wd = seg.element_hparams_shard(b, s, N_SHARDS)
            assert sc.shape == (span,)
            assert (sc == sc_full[s * span:(s + 1) * span]).all()
            assert (wd == wd_full[s * span:(s + 1) * span]).all()
    with pytest.raises(ValueError, match="does not split"):
        seg.element_hparams_shard(0, 0, N_SHARDS + 1)


# ---------------------------------------------------------------------------
# Structural O(buckets) claim on the sharded update path
# ---------------------------------------------------------------------------
def test_sharded_update_is_o_buckets_not_o_leaves():
    """Same structural claim as the replicated engine's jaxpr op-count
    test, on the RS path: the sharded fused apply (one kernel per bucket
    span + pre-mask + slice) grows with the bucket count; a ZeRO-style
    per-leaf update over the equivalent 1/N state grows with the leaf
    count.  Wall clock on CPU is load-noisy; this is deterministic."""
    from repro.optim.optimizers import apply_updates, init_opt_state
    from test_bucket_update import _count_eqns

    n_leaves, leaf_elems, n_buckets, n_shards = 64, 512, 4, 4
    key = jax.random.PRNGKey(5)
    tree = {
        f"l{i:03d}": jax.random.normal(jax.random.fold_in(key, i),
                                       (leaf_elems,))
        for i in range(n_leaves)
    }
    grads = jax.tree.map(lambda p: p * 0.01, tree)
    bo = tuple(i * n_buckets // n_leaves for i in range(n_leaves))
    layout = build_bucket_layout(tree, bo, n_buckets, shard_count=n_shards)
    spec = adamw(1e-3)
    seg = build_segments(layout, spec)
    pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(tree)))
    gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
    opt_f = init_flat_opt_state(spec, layout.buf_sizes)
    p_s = _shard_state(layout, pbuf, 0)
    g_s = _shard_state(layout, gbuf, 0)
    o_s = {"step": opt_f["step"], "m": _shard_state(layout, opt_f["m"], 0),
           "v": _shard_state(layout, opt_f["v"], 0)}

    n_flat = _count_eqns(jax.make_jaxpr(
        lambda p, g, o, i: apply_bucket_updates(
            spec, seg, p, g, o, grad_scale=0.1, shard_id=i,
            norm_psum=lambda t: t)[:2]
    )(p_s, g_s, o_s, jnp.int32(0)).jaxpr)

    # ZeRO per-leaf reference: every leaf sharded 1/N, still one op
    # sequence per leaf
    shard_tree = jax.tree.map(lambda x: x[: x.size // n_shards], tree)
    shard_grads = jax.tree.map(lambda x: x[: x.size // n_shards], grads)
    opt_l = init_opt_state(spec, shard_tree)
    n_leaf = _count_eqns(jax.make_jaxpr(
        lambda p, g, o: apply_updates(spec, p, g, o, grad_scale=0.1)
    )(shard_tree, shard_grads, opt_l).jaxpr)

    assert n_flat < n_leaf / 4, (n_flat, n_leaf)
    assert n_leaf > n_leaves


# ---------------------------------------------------------------------------
# bf16 compute against the f32 flat master (mixed-precision satellite)
# ---------------------------------------------------------------------------
def test_flat_bf16_matches_tree_bf16_reference(single_mesh):
    """flat_state + compute_dtype=bf16: the forward/backward runs in
    bf16 (cast at the buffer views), the master copy and moments stay
    f32.  Against the tree-path bf16 runtime (params *stored* bf16) the
    trajectories agree to bf16 rounding: the first update is identical
    (both inits are the same bf16 draw, both apply in f32), after which
    the master accumulates what the tree path rounds away — the gap per
    period stays well under one bf16 ulp of the weights."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.train import DeftRuntime, init_train_state
    from test_train_steps import B, S, _schedule_for

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    probe = init_train_state(key, cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=1.8)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    del probe

    with single_mesh:
        rt_f = DeftRuntime(cfg, opt, sched, layout, single_mesh,
                           compute_dtype=jnp.bfloat16)
        rt_t = DeftRuntime(cfg, opt, sched, layout, single_mesh,
                           flat_state=False)
        s_f = rt_f.init_state(key, dtype=jnp.bfloat16)
        s_t = rt_t.init_state(key, dtype=jnp.bfloat16)
        # identical starting point: the f32 master holds the exact bf16
        # init values
        for a, b in zip(jax.tree.leaves(rt_f.params_tree(s_f)),
                        jax.tree.leaves(s_t["params"])):
            assert a.dtype == jnp.float32 and b.dtype == jnp.bfloat16
            assert bool(jnp.array_equal(a, b.astype(jnp.float32)))
        for step in range(2 * sched.period):
            batch = make_batch(cfg, 0, step, B, S)
            s_f, m_f = rt_f.step(step, s_f, batch)
            s_t, m_t = rt_t.step(step, s_t, batch)
            diff = max(
                float(jnp.max(jnp.abs(a - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(rt_f.params_tree(s_f)),
                                jax.tree.leaves(rt_t.params_tree(s_t)))
            )
            assert diff < 5e-3, f"step {step}: bf16 paths diverged {diff}"
        assert rt_f.stats()["compute_dtype"] == "bfloat16"


def test_flat_bf16_requires_matching_compute_dtype(single_mesh):
    from repro.configs import get_config, reduce_for_smoke
    from repro.train import DeftRuntime, init_train_state
    from test_train_steps import _schedule_for

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    probe = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=0.5)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    with single_mesh:
        rt = DeftRuntime(cfg, opt, sched, layout, single_mesh)
        with pytest.raises(ValueError, match="compute_dtype"):
            rt.init_state(jax.random.PRNGKey(0), dtype=jnp.bfloat16)


# ---------------------------------------------------------------------------
# True multi-device end-to-end equivalence (4 forced host devices)
# ---------------------------------------------------------------------------
_SCRIPT = r"""
import dataclasses
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import solve_schedule
from repro.core.scheduler import SchedulerConfig
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.models.model import loss_fn
from repro.optim.optimizers import adamw, apply_updates, init_opt_state
from repro.train import (DeftRuntime, assign_buckets, build_bucket_layout,
                         init_train_state, leaf_bucket_times)

mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
cfg = reduce_for_smoke(get_config("qwen3-4b"))
opt = adamw(1e-3)
key = jax.random.PRNGKey(0)
probe = init_train_state(key, cfg, opt)
bucket_of, nb = assign_buckets(probe["params"], cfg, partition_elems=150_000)
B, S = 8, 32
times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                          HardwareModel(dp_degree=4), S, 2)
scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
times = BucketTimes(times.fwd, times.bwd, tuple(c * scale for c in times.comm))
sched = solve_schedule(times, SchedulerConfig())
assert sched.updates_per_period < sched.period, "want a merging schedule"

# force one rotating sync phase onto the secondary link so the
# hierarchical chain is exercised end to end
phases, forced = [], False
for ph in sched.phases:
    if not forced and ph.rotate and any(r == "sync" for r in ph.route_new):
        sec = tuple(r == "sync" for r in ph.route_new)
        phases.append(dataclasses.replace(ph, secondary=sec))
        forced = True
    else:
        phases.append(ph)
assert forced, "schedule has no rotating sync phase to mark secondary"
sched = dataclasses.replace(sched, phases=tuple(phases))

lay_sh = build_bucket_layout(probe["params"], bucket_of, nb, shard_count=2)
lay_rep = build_bucket_layout(probe["params"], bucket_of, nb)

# python-level gradient-accumulation reference (global gradients)
ref_params = probe["params"]
ref_opt = init_opt_state(opt, ref_params)
zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             ref_params)
ref_cur, ref_fut = zeros(), zeros()
gfn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))

with mesh:
    rt = DeftRuntime(cfg, opt, sched, lay_sh, mesh, fsdp=True)
    assert rt.flat_state and rt.stats()["sharded_state"]
    state = rt.init_state(key)
    # 1/N residency: every param/moment buffer is split over 'data' and
    # each device holds exactly one span
    for part in (state["pbuf"], state["opt"]["m"], state["opt"]["v"]):
        for b, a in enumerate(part):
            assert a.sharding.spec == P("data"), a.sharding
            shard_elems = {s.data.size for s in a.addressable_shards}
            assert shard_elems == {lay_sh.shard_sizes[b]}
    rt.compile(state, make_batch(cfg, 0, 0, B, S))

    # replicated flat engine over the same (pod, data) axes: the
    # semantics twin with full-size resident buffers
    rt_rep = DeftRuntime(cfg, opt, sched, lay_rep, mesh, multi_pod=True)
    state_rep = rt_rep.init_state(key)

    for step in range(2 * sched.period):
        batch = make_batch(cfg, 0, step, B, S)
        ph = sched.phases[step % sched.period]
        prev = state
        state, m = rt.step(step, state, batch)
        assert all(x.is_deleted() for x in jax.tree.leaves(prev)), \
            "donation must hold on the sharded engine"
        state_rep, m_rep = rt_rep.step(step, state_rep, batch)

        g = gfn(ref_params, batch)
        if ph.rotate:
            gen = jax.tree.map(lambda a, b: a.astype(jnp.float32) + b, g,
                               ref_fut)
            ref_fut = jax.tree.map(jnp.zeros_like, ref_fut)
        else:
            ref_fut = jax.tree.map(lambda f, a: f + a.astype(jnp.float32),
                                   ref_fut, g)
            gen = None
        if ph.do_update:
            src = ref_cur if ph.update_source == "cur" else gen
            ref_params, ref_opt = apply_updates(
                opt, ref_params, src, ref_opt, grad_scale=1.0 / ph.update_k)
            ref_cur = gen if ph.update_source == "cur" else \
                jax.tree.map(jnp.zeros_like, ref_cur)
        elif ph.rotate:
            ref_cur = gen
        got = jax.tree.leaves(rt.params_tree(state))
        diff_ref = max(float(jnp.max(jnp.abs(a - b)))
                       for a, b in zip(got, jax.tree.leaves(ref_params)))
        assert diff_ref < 1e-4, f"step {step}: vs reference {diff_ref}"
        diff_rep = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(got,
                            jax.tree.leaves(rt_rep.params_tree(state_rep))))
        # same update math; only collective summation order differs
        assert diff_rep < 2e-6, f"step {step}: vs replicated {diff_rep}"

    # checkpoint boundary roundtrips exactly through the sharded form
    tree_state = rt.state_to_tree(state)
    back = rt.tree_to_state(tree_state)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        assert bool(jnp.array_equal(a, b)), "sharded roundtrip not exact"

# tree-state RS reference (flat_state=False, XLA-auto FSDP): the
# partial-manual + FSDP-constraint graph aborts on jaxlib < 0.5
# (DESIGN.md par.6), so the comparison runs on jax >= 0.5 only
_v = tuple(int(x) for x in jax.__version__.split(".")[:2])
if _v >= (0, 5):
    with mesh:
        rt_tree = DeftRuntime(cfg, opt, sched, lay_rep, mesh, fsdp=True,
                              flat_state=False)
        state_t = rt_tree.init_state(key)
        state_s = rt.init_state(key)
        for step in range(sched.period + 1):
            batch = make_batch(cfg, 0, step, B, S)
            state_t, _ = rt_tree.step(step, state_t, batch)
            state_s, _ = rt.step(step, state_s, batch)
        diff = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(rt.params_tree(state_s)),
                            jax.tree.leaves(rt_tree.params_tree(state_t))))
        assert diff < 1e-5, f"sharded vs tree-RS reference: {diff}"
        print(f"TREE_RS_COMPARED diff={diff:.2e}")
else:
    print("tree-RS comparison skipped (jaxlib partial-manual CHECK, "
          f"jax {jax.__version__})")
print("FLAT_FSDP_OK")
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_flat_fsdp_engine_on_4_devices(tmp_path):
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script), src],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "FLAT_FSDP_OK" in out.stdout
