"""Online adaptive control plane: telemetry -> calibration -> drift
detection -> Preserver-gated replan -> DeftRuntime hot-swap.

The acceptance test at the bottom runs the whole loop against the real
fused runtime with a synthetic bandwidth drop injected mid-run and
asserts the final parameters BIT-MATCH a reference run that executes the
same effective phase sequence (old schedule up to the swap boundary, new
schedule after) — the hot-swap is semantically a pure re-planning, never
a perturbation of training state.
"""
import dataclasses
import math
import os
import sys

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.adapt import (
    AdaptConfig,
    AdaptiveController,
    BandwidthDrop,
    SyntheticTelemetrySource,
    Telemetry,
    TelemetryConfig,
    calibrate,
    fit_scales,
    run_control_loop,
    scale_times,
    schedule_plans,
    steady_phase_durations,
)
from repro.adapt.calibrate import fit_horizon
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import feedback_solve
from repro.core.knapsack import (
    clear_knapsack_caches,
    knapsack_cache_info,
    set_knapsack_memoization,
)
from repro.core.preserver import WalkParams
from repro.core.scheduler import DeftScheduler, SchedulerConfig
from repro.core.simulator import simulate_deft
from repro.data.pipeline import make_batch
from repro.optim.optimizers import adamw
from repro.train import (
    DeftRuntime,
    assign_buckets,
    build_bucket_layout,
    leaf_bucket_times,
)
from repro.core.profiler import HardwareModel
from repro.models.model import init_params


WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)


def _toy_times(n=8, cr=1.8, seed=0):
    import random

    rng = random.Random(seed)
    fwd = tuple(rng.uniform(0.002, 0.02) for _ in range(n))
    bwd = tuple(2 * f for f in fwd)
    comm = tuple(rng.uniform(0.005, 0.08) for _ in range(n))
    t = BucketTimes(fwd, bwd, comm)
    scale = cr * (t.fwd_total + t.bwd_total) / t.comm_total
    return BucketTimes(fwd, bwd, tuple(c * scale for c in comm))


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------
def test_telemetry_ring_bound_and_ema():
    tel = Telemetry(2, TelemetryConfig(ring_size=8, ema_alpha=0.5,
                                       warmup_steps=2))
    for i in range(20):
        tel.record(i, i % 2, 1.0 + (i % 2), loss=float(i))
    assert len(tel) == 8                      # ring bounded
    assert len(tel.losses()) == 8
    # EMA converged near the per-phase constant values
    assert tel.phase_time(0) == pytest.approx(1.0, abs=1e-6)
    assert tel.phase_time(1) == pytest.approx(2.0, abs=1e-6)
    assert tel.ready()


def test_telemetry_warmup_skip():
    tel = Telemetry(1, TelemetryConfig(warmup_steps=3))
    tel.record(0, 0, 100.0)   # compile-jitter samples must not pollute
    tel.record(1, 0, 100.0)
    tel.record(2, 0, 100.0)
    assert tel.phase_time(0) is None
    assert not tel.ready()
    tel.record(3, 0, 1.0)
    assert tel.phase_time(0) == pytest.approx(1.0)
    assert tel.ready()


def test_telemetry_rebase_keeps_losses_rearms_warmup():
    tel = Telemetry(2, TelemetryConfig(warmup_steps=1))
    for i in range(6):
        tel.record(i, i % 2, 1.0, loss=2.5)
    assert tel.ready()
    tel.rebase(3)
    assert tel.n_phases == 3
    assert not tel.ready()                    # EMAs re-keyed
    assert len(tel.losses()) == 6             # loss trace survives
    tel.record(6, 0, 1.0)                     # warm-up sample (skipped)
    tel.record(7, 1, 1.0)
    assert tel.phase_time(0) is None and tel.phase_time(1) is not None


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------
def test_fit_scales_recovers_injected_degradation():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = schedule_plans(times, scfg, horizon=fit_horizon(schedule.period))
    for true_a, true_b in ((1.0, 3.0), (1.5, 1.0)):
        measured = steady_phase_durations(
            plans, scale_times(times, true_a, true_b), schedule.period,
            mu=scfg.mu, heterogeneous=scfg.heterogeneous,
        )
        a, b, resid = fit_scales(times, scfg, schedule.period, measured)
        assert a == pytest.approx(true_a, rel=0.15), (true_a, true_b)
        assert b == pytest.approx(true_b, rel=0.15), (true_a, true_b)


def test_fit_scales_faster_link_is_not_misread_as_drift():
    """A link FASTER than planned overlaps completely — (a, b) is only
    identifiable up to a plateau.  The fit must settle near (1, 1), not
    wander to a plateau corner that would trigger spurious replans."""
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = schedule_plans(times, scfg, horizon=fit_horizon(schedule.period))
    measured = steady_phase_durations(
        plans, scale_times(times, 1.0, 0.5), schedule.period,
        mu=scfg.mu, heterogeneous=scfg.heterogeneous,
    )
    a, b, _ = fit_scales(times, scfg, schedule.period, measured)
    assert a == pytest.approx(1.0, rel=0.15)
    assert 0.3 <= b <= 1.1


def test_per_link_fit_recovers_secondary_only_degradation():
    """A secondary-only slowdown (slow host/DCN path congests, primary
    fabric holds) is exactly what the 2-D fit cannot express — its
    comm_scale moves both links.  The staged per-link fit (§14) must
    recover the multiplier on the secondary link and hand back
    LinkModels whose forward simulation matches the measurements at
    least as well as the 2-D fit alone."""
    from repro.core.links import LinkModel

    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = schedule_plans(times, scfg, horizon=fit_horizon(schedule.period))
    true_s = 3.0
    base = scfg.models()
    degraded = {
        lid: (m if lid == 0 else LinkModel(m.latency, m.inv_bw * true_s))
        for lid, m in base.items()
    }
    measured = steady_phase_durations(
        plans, times, schedule.period,
        mu=scfg.mu, heterogeneous=scfg.heterogeneous, link_models=degraded,
    )
    prof2d = calibrate(times, scfg, schedule.period, measured)
    prof = calibrate(times, scfg, schedule.period, measured, per_link=True)
    assert prof.link_models is not None
    # the degradation lands on the secondary link, not the joint scale:
    # comm_scale * sec_scale carries the true multiplier between them,
    # with the per-link stage providing the secondary-specific part
    assert prof.sec_scale > 1.2
    assert prof.comm_scale * prof.sec_scale == pytest.approx(
        true_s, rel=0.35
    )
    assert prof.comp_scale == pytest.approx(1.0, rel=0.15)
    # per-link forward model explains the data no worse than 2-D alone
    assert prof.residual <= prof2d.residual + 1e-12
    assert prof.drift > 0.2
    # fitted models are consumable: secondary inv_bw grew, primary fixed
    assert prof.link_models[0].inv_bw == pytest.approx(base[0].inv_bw)
    assert prof.link_models[1].inv_bw > base[1].inv_bw


def test_per_link_fit_noop_when_homogeneous_or_clean():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = schedule_plans(times, scfg, horizon=fit_horizon(schedule.period))
    measured = steady_phase_durations(
        plans, times, schedule.period,
        mu=scfg.mu, heterogeneous=scfg.heterogeneous,
    )
    # clean measurements: the regularized 1-D stage stays at 1.0
    prof = calibrate(times, scfg, schedule.period, measured, per_link=True)
    assert prof.sec_scale == pytest.approx(1.0, rel=0.1)
    # homogeneous config: the stage is skipped entirely
    homo = dataclasses.replace(scfg, heterogeneous=False)
    prof_h = calibrate(times, homo, schedule.period, measured, per_link=True)
    assert prof_h.sec_scale == 1.0 and prof_h.link_models is None


def test_calibrate_rebases_times_and_hardware_model():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = schedule_plans(times, scfg, horizon=fit_horizon(schedule.period))
    measured = steady_phase_durations(
        plans, scale_times(times, 1.0, 2.0), schedule.period,
        mu=scfg.mu, heterogeneous=scfg.heterogeneous,
    )
    hw = HardwareModel()
    prof = calibrate(times, scfg, schedule.period, measured, hw=hw)
    assert prof.drift > 0.5
    # comm times re-based up, effective bandwidth re-based down
    assert prof.times.comm_total == pytest.approx(
        times.comm_total * prof.comm_scale
    )
    assert prof.hw.ici_bw == pytest.approx(hw.ici_bw / prof.comm_scale)
    assert prof.times.coverage_rate > times.coverage_rate


def test_calibrate_no_drift_when_measurements_match_plan():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    plans = schedule_plans(times, scfg, horizon=fit_horizon(schedule.period))
    measured = steady_phase_durations(
        plans, times, schedule.period,
        mu=scfg.mu, heterogeneous=scfg.heterogeneous,
    )
    prof = calibrate(times, scfg, schedule.period, measured)
    assert prof.drift < 0.05


# ---------------------------------------------------------------------------
# Controller: drift detection and replanning (pure Python, deterministic)
# ---------------------------------------------------------------------------
def _drive(ctrl, src, steps, losses=None):
    """Run the shared synthetic control loop; returns the event list."""
    return run_control_loop(ctrl, src, steps, losses=losses)


def test_controller_detects_bandwidth_drop_and_replans():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    drop = BandwidthDrop(step=40, comm_scale=3.0)
    ctrl = AdaptiveController(times, schedule, scfg, walk=WALK)
    events = _drive(ctrl, SyntheticTelemetrySource(times, drop), 120)
    assert events, "no replan despite a 3x bandwidth drop"
    assert all(e.step >= drop.step for e in events), "replanned before drop"
    assert events[0].trigger == "timing-drift"
    assert events[0].profile.comm_scale > 1.2
    assert events[0].coverage_delta > 0      # degraded link -> higher CR
    # cumulative calibration converges on the injected degradation
    cum = 1.0
    for e in events:
        cum *= e.profile.comm_scale
    assert cum == pytest.approx(drop.comm_scale, rel=0.2)
    # the replanned schedule beats the stale one on the degraded link
    degraded = scale_times(times, 1.0, drop.comm_scale)
    stale = simulate_deft(
        degraded, DeftScheduler(times, scfg).run(48),
        mu=scfg.mu, heterogeneous=scfg.heterogeneous,
    )
    final = ctrl.scheduler_cfg
    adapted = simulate_deft(
        degraded, DeftScheduler(ctrl.times, final).run(48),
        mu=final.mu, heterogeneous=final.heterogeneous,
    )
    assert adapted.iteration_time <= stale.iteration_time * 1.001


def test_controller_quiet_without_drift():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=10**9, comm_scale=3.0)
    )
    ctrl = AdaptiveController(times, schedule, scfg, walk=WALK)
    assert _drive(ctrl, src, 80) == []


def test_controller_preserver_flip_on_measured_walk():
    """Timing steady, but the measured loss trace makes the Preserver
    reject the installed merged schedule -> 'preserver-flip' replan with
    a higher update frequency."""
    times = _toy_times(cr=2.5)
    schedule, verdict, scfg, _ = feedback_solve(times, WALK, eps=1e9)
    assert schedule.updates_per_period < schedule.period
    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=10**9, comm_scale=1.0)
    )
    # near-converged noisy trace: batch-size sensitivity is maximal near
    # S*, so the merged k-sequence fails a tight eps under measured walk
    import random

    rng = random.Random(3)
    losses = [abs(rng.gauss(0.02, 0.02)) for _ in range(200)]
    ctrl = AdaptiveController(
        times, schedule, scfg, walk=WALK,
        cfg=AdaptConfig(eps=1e-4, eta=0.05, base_batch=16),
    )
    events = _drive(ctrl, src, 200, losses=losses)
    assert any(e.trigger == "preserver-flip" for e in events)
    ev = next(e for e in events if e.trigger == "preserver-flip")
    new_freq = len(ev.new_batch_seq) / ev.new_period
    old_freq = len(ev.old_batch_seq) / ev.old_period
    assert new_freq >= old_freq


def test_knapsack_memo_cache_reused_across_consecutive_replans():
    """Consecutive replans over a similar profile re-solve mostly
    cache-hit knapsack instances (the solver fast path the control plane
    leans on to stay off the hot path)."""
    times = _toy_times()
    prev = set_knapsack_memoization(True)
    try:
        clear_knapsack_caches()
        feedback_solve(times, WALK)
        first = knapsack_cache_info()
        feedback_solve(times, WALK)           # identical replan: all hits
        second = knapsack_cache_info()
        assert second.misses == first.misses
        assert second.hits > first.hits
        # a *calibrated* (scaled-comm) replan still reuses the identical
        # compute-capacity instances solved during forward stages
        feedback_solve(scale_times(times, 1.0, 1.3), WALK)
        third = knapsack_cache_info()
        assert third.hits > second.hits
    finally:
        set_knapsack_memoization(prev)


# ---------------------------------------------------------------------------
# Candidate-partition path (pure Python; the runtime side lives in
# tests/test_repack.py)
# ---------------------------------------------------------------------------
def _leaf_model_setup(pe=20_000, cr=1.8):
    from repro.train import build_leaf_time_model

    cfg = _tiny_cfg()
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    model = build_leaf_time_model(params, cfg, HardwareModel(dp_degree=4),
                                  32, 4)
    bo, nb = model.partition(pe)
    model = model.with_coverage_rate(bo, nb, cr)
    return model, bo, nb, model.bucket_times(bo, nb)


def _synthetic_leaf_model(fwd, elems, comm_scale=1.0):
    from repro.train.bucketing import LeafTimeModel

    return LeafTimeModel(
        order=tuple(range(len(fwd))),
        fwd_s=tuple(fwd),
        elems=tuple(int(e) for e in elems),
        hw=HardwareModel(dp_degree=4),
        comm_scale=comm_scale,
    )


leaf_atoms = st.integers(min_value=2, max_value=24).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(1e-5, 5e-3), min_size=n, max_size=n),
        st.lists(st.integers(1_000, 2_000_000), min_size=n, max_size=n),
    )
)


@settings(max_examples=40)
@given(leaf_atoms, st.floats(0.3, 3.0), st.floats(0.3, 3.0))
def test_dp_partition_no_worse_than_greedy_on_surrogate(atoms, a, b):
    """The boundary DP is exact over ALL contiguous partitions, so under
    its own objective it can never lose to the greedy size-targeted fill
    — at any calibrated (comp, comm) scale and any grid factor."""
    from repro.adapt import dp_partition, exposed_makespan

    fwd, elems = atoms
    model = _synthetic_leaf_model(fwd, elems)
    dp_bo, dp_nb = dp_partition(model, comp_scale=a, comm_scale=b)
    assert dp_nb >= 1 and len(dp_bo) == len(fwd)
    dp_cost = exposed_makespan(model, dp_bo, dp_nb,
                               comp_scale=a, comm_scale=b)
    total = sum(elems)
    for frac in (0.05, 0.25, 1.0):
        g_bo, g_nb = model.partition(max(int(total * frac), 1))
        g_cost = exposed_makespan(model, g_bo, g_nb,
                                  comp_scale=a, comm_scale=b)
        assert dp_cost <= g_cost + 1e-12


def test_dp_partition_shape_and_bounded_variant():
    """DP output is a valid ascending contiguous model-order partition;
    the ``max_buckets`` layered variant respects its cap and can only be
    costlier than the unbounded optimum."""
    from repro.adapt import dp_partition, exposed_makespan

    model, bo, nb, _ = _leaf_model_setup()
    dp_bo, dp_nb = dp_partition(model)
    # every leaf assigned; buckets ascending+contiguous along model order
    seq = [dp_bo[i] for i in model.order]
    assert seq[0] == 0 and seq[-1] == dp_nb - 1
    assert all(s2 - s1 in (0, 1) for s1, s2 in zip(seq, seq[1:]))
    free = exposed_makespan(model, dp_bo, dp_nb)
    for cap in (1, 2, max(dp_nb - 1, 1)):
        c_bo, c_nb = dp_partition(model, max_buckets=cap)
        assert 1 <= c_nb <= cap
        assert exposed_makespan(model, c_bo, c_nb) >= free - 1e-12
    # empty tree degenerates cleanly
    empty = _synthetic_leaf_model((), ())
    assert dp_partition(empty) == ((), 0)


def test_repartitioner_candidate_superset_includes_dp():
    """The candidate grid is {current} ∪ factor grid ∪ DP; ``use_dp``
    gates the DP member and the DP candidate reprices with the
    cumulative drift scales."""
    from repro.adapt import RepartitionConfig, Repartitioner

    model, bo, nb, _ = _leaf_model_setup()
    rp = Repartitioner(model, RepartitionConfig(base_partition_elems=20_000))
    cands = rp.candidates(bo, nb, comm_scale=3.0)
    tags = [c.tag for c in cands]
    assert tags[0] == "current"
    assert "dp" in tags
    dp_cand = next(c for c in cands if c.tag == "dp")
    assert dp_cand.n_buckets >= 1
    assert rp.times_for(dp_cand).n == dp_cand.n_buckets
    off = Repartitioner(model, RepartitionConfig(
        base_partition_elems=20_000, use_dp=False))
    assert "dp" not in [c.tag for c in off.candidates(bo, nb)]


def test_feedback_solve_candidates_gate_and_hysteresis():
    """The winner is Preserver-ok (or the baseline itself), and an
    impossible min_gain pins the choice to the baseline — near-ties
    must never pay a re-pack."""
    from repro.adapt import RepartitionConfig, Repartitioner
    from repro.core.deft import feedback_solve_candidates

    model, bo, nb, times = _leaf_model_setup()
    rp = Repartitioner(model, RepartitionConfig(base_partition_elems=20_000))
    pairs = [(c.tag, rp.times_for(c, comm_scale=3.0))
             for c in rp.candidates(bo, nb)]
    best, solves = feedback_solve_candidates(
        pairs, WALK, baseline_tag="current", min_gain=0.02
    )
    assert len(solves) == len(pairs)
    assert best.verdict.ok or best.tag == "current"
    assert all(s.iteration_time > 0 for s in solves)
    # the winner actually wins on simulated iteration time
    ok = [s for s in solves if s.verdict.ok]
    assert best.iteration_time == min(s.iteration_time for s in ok)
    pinned, _ = feedback_solve_candidates(
        pairs, WALK, baseline_tag="current", min_gain=10.0
    )
    assert pinned.tag == "current"


def test_controller_repartitions_on_bandwidth_drop():
    """A 3x bandwidth drop calibrates to a profile under which a
    different partition wins -> the replan is partition-changing, the
    adopted candidate is Preserver-gated, and the controller's installed
    view (times.n, bucket_of) follows the new partition."""
    from repro.adapt import RepartitionConfig, Repartitioner

    model, bo, nb, times = _leaf_model_setup()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    rp = Repartitioner(model, RepartitionConfig(base_partition_elems=20_000))
    drop = BandwidthDrop(step=40, comm_scale=3.0)
    ctrl = AdaptiveController(times, schedule, scfg, walk=WALK,
                              repartitioner=rp, bucket_of=bo)
    events = run_control_loop(
        ctrl, SyntheticTelemetrySource(times, drop), 140,
        run_base_fn=lambda e: rp.base_times_for(e.partition),
    )
    assert events and all(e.step >= drop.step for e in events)
    reparts = [e for e in events if e.partition_changed]
    assert reparts, "calibrated drop profile favored no other partition"
    ev = reparts[0]
    assert ev.verdict.ok
    assert ev.new_n_buckets == ev.partition.n_buckets != ev.old_n_buckets
    assert ev.changed and "REPARTITION" in ev.describe()
    assert len(ev.candidate_solves) >= 2
    assert ctrl.stats()["repartitions"] == len(reparts)
    assert ctrl.bucket_of == reparts[-1].partition.bucket_of
    assert ctrl.times.n == reparts[-1].new_n_buckets


def test_controller_without_repartitioner_never_repartitions():
    times = _toy_times()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    ctrl = AdaptiveController(times, schedule, scfg, walk=WALK)
    events = run_control_loop(
        ctrl, SyntheticTelemetrySource(
            times, BandwidthDrop(step=40, comm_scale=3.0)), 120,
    )
    assert events
    assert all(not e.partition_changed for e in events)
    assert ctrl.stats()["repartitions"] == 0


def test_controller_repartitioner_requires_bucket_of():
    from repro.adapt import RepartitionConfig, Repartitioner

    model, bo, nb, times = _leaf_model_setup()
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    rp = Repartitioner(model, RepartitionConfig(base_partition_elems=20_000))
    with pytest.raises(ValueError, match="bucket_of"):
        AdaptiveController(times, schedule, scfg, walk=WALK,
                           repartitioner=rp)


# ---------------------------------------------------------------------------
# The acceptance test: detect -> replan -> hot-swap on the real runtime,
# bit-matching a reference run of the same effective phase sequence.
# ---------------------------------------------------------------------------
B, S = 4, 32


def _tiny_cfg():
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )


def test_adaptive_loop_hot_swap_bit_matches_reference(single_mesh):
    cfg = _tiny_cfg()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    bucket_of, nb = assign_buckets(params, cfg, partition_elems=20_000)
    hw = HardwareModel(dp_degree=2)
    times = leaf_bucket_times(params, cfg, bucket_of, nb, hw, S, B)
    scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    schedule, _, scfg, _ = feedback_solve(times, WALK)
    layout = build_bucket_layout(params, bucket_of, nb)

    drop = BandwidthDrop(step=4, comm_scale=3.0)
    src = SyntheticTelemetrySource(times, drop)
    ctrl = AdaptiveController(
        times, schedule, scfg, walk=WALK,
        cfg=AdaptConfig(warmup_steps=2, check_every=2, cooldown_steps=100,
                        min_loss_samples=10**9),  # timing trigger only
    )

    n_steps = 6 * schedule.period + 8
    runtime = DeftRuntime(cfg, opt, schedule, layout, single_mesh)
    state = runtime.init_state(key)
    swap_info = None
    new_schedule = None
    with jax.set_mesh(single_mesh):
        for step in range(n_steps):
            batch = make_batch(cfg, 0, step, B, S)
            state, m = runtime.step(step, state, batch)
            wall = src.wall_time(
                step, ctrl.schedule, ctrl.scheduler_cfg,
                runtime.last_phase, solve_times=ctrl.times,
            )
            event = ctrl.observe(step, runtime.last_phase, wall)
            if event is not None and event.changed:
                assert new_schedule is None, "cooldown should allow 1 swap"
                new_schedule = event.schedule
                swap_info = runtime.prepare_swap(
                    new_schedule, state, batch, background=False
                )

    # the controller detected the drop and the runtime swapped once, at a
    # cycle boundary of the old schedule
    assert new_schedule is not None, "no replan despite 3x bandwidth drop"
    assert new_schedule.phases != schedule.phases
    st = runtime.stats()
    assert st["replans"] == 1 and st["hot_swaps"] == 1
    swap_step = runtime.swap_log[0]["step"]
    assert swap_step % schedule.period == 0
    assert runtime.period == new_schedule.period
    assert st["steps_dispatched"] == n_steps
    assert st["steps_per_s"] > 0

    # staging the same schedule again is a pure cache hit
    re_info = runtime.prepare_swap(
        new_schedule, state, make_batch(cfg, 0, 0, B, S), background=False
    )
    assert re_info["new_phases"] == 0
    assert swap_info["new_phases"] + swap_info["reused_phases"] == len(
        new_schedule.phases
    )

    # ---- reference: the same effective update sequence, run explicitly
    rt_a = DeftRuntime(cfg, opt, schedule, layout, single_mesh)
    ref_state = rt_a.init_state(key)
    rt_b = DeftRuntime(cfg, opt, new_schedule, layout, single_mesh)
    with jax.set_mesh(single_mesh):
        for step in range(swap_step):
            ref_state, _ = rt_a.step(step, ref_state,
                                     make_batch(cfg, 0, step, B, S))
        for step in range(swap_step, n_steps):
            ref_state, _ = rt_b.step(step - swap_step, ref_state,
                                     make_batch(cfg, 0, step, B, S))

    for a, b in zip(jax.tree.leaves(runtime.params_tree(state)),
                    jax.tree.leaves(rt_b.params_tree(ref_state))):
        assert jnp.array_equal(a, b), "hot-swapped run diverged bitwise"


# ---------------------------------------------------------------------------
# The benchmark's acceptance claim, exercised as a test
# ---------------------------------------------------------------------------
def test_adapt_bench_adaptive_at_least_static(tmp_path, monkeypatch):
    monkeypatch.setenv("BENCH_ADAPT_OUT", str(tmp_path / "BENCH_adapt.json"))
    monkeypatch.setenv("BENCH_ADAPT_STEPS", "120")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        import importlib

        import benchmarks.adapt_bench as ab

        importlib.reload(ab)
        ab.run()
    finally:
        sys.path.pop(0)
    import json

    out = json.load(open(tmp_path / "BENCH_adapt.json"))
    assert out["replan_events"], "bench scenario produced no replans"
    assert (
        out["steps_per_s_adaptive_after_drop"]
        >= out["steps_per_s_static_after_drop"]
    )
    # cache trail shows the memoized solver absorbing consecutive replans
    assert out["knapsack_cache_trail"][-1]["hits"] > 0
