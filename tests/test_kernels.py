"""Kernel correctness: Pallas kernels (interpret=True) and the flash
custom-VJP twins, swept over shapes/dtypes against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash import flash_global, flash_local
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.rglru.kernel import rglru_scan_pallas
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_reference
from repro.kernels.rwkv6.kernel import rwkv6_pallas
from repro.kernels.rwkv6.ops import rwkv6_mix
from repro.kernels.rwkv6.ref import rwkv6_reference


def _qkv(key, b, sq, sk, h, kvh, d, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, h, d)).astype(dtype)
    k = jax.random.normal(kk, (b, sk, kvh, d)).astype(dtype)
    v = jax.random.normal(kv, (b, sk, kvh, d)).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# Pallas flash attention (interpret mode) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "sq,h,kvh,d,causal,window,softcap",
    [
        (128, 4, 4, 64, True, 0, 0.0),
        (128, 4, 2, 64, True, 0, 50.0),
        (256, 4, 1, 32, True, 64, 0.0),     # sliding window + GQA
        (128, 2, 2, 128, False, 0, 0.0),    # bidirectional (encoder)
    ],
)
def test_pallas_flash_vs_ref(sq, h, kvh, d, causal, window, softcap, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, sq, sq, h, kvh, d, dtype)
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal, window=window, softcap=softcap,
        block_q=64, block_kv=64, interpret=True,
    ).transpose(0, 2, 1, 3)
    want = attention_reference(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


# ---------------------------------------------------------------------------
# flash custom-VJP twins vs oracle (values AND gradients)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize(
    "sq,sk,h,kvh,d,causal,softcap,qoff,chunk",
    [
        (64, 64, 4, 2, 32, True, 0.0, 0, 16),
        (64, 64, 4, 4, 32, True, 50.0, 0, 32),
        (48, 80, 4, 1, 16, False, 0.0, 0, 32),
        (37, 53, 2, 2, 8, True, 0.0, 16, 16),   # ragged + offset
    ],
)
def test_flash_global_value_and_grad(sq, sk, h, kvh, d, causal, softcap,
                                     qoff, chunk, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, sq, sk, h, kvh, d, dtype)

    f_new = lambda q, k, v: jnp.sum(
        jnp.sin(flash_global(q, k, v, causal, softcap, qoff, chunk))
    )
    f_ref = lambda q, k, v: jnp.sum(
        jnp.sin(attention_reference(q, k, v, causal=causal, softcap=softcap,
                                    q_offset=qoff))
    )
    np.testing.assert_allclose(f_new(q, k, v), f_ref(q, k, v), rtol=1e-5)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_new, g_ref):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize(
    "sq,h,kvh,d,window,softcap,bq",
    [
        (128, 4, 2, 32, 32, 0.0, 32),
        (100, 4, 4, 16, 48, 30.0, 32),   # ragged q + softcap
        (64, 2, 1, 8, 16, 0.0, 64),
    ],
)
def test_flash_local_value_and_grad(sq, h, kvh, d, window, softcap, bq):
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, sq, sq, h, kvh, d, jnp.float32)
    f_new = lambda q, k, v: jnp.sum(
        jnp.sin(flash_local(q, k, v, window, softcap, 0, bq))
    )
    f_ref = lambda q, k, v: jnp.sum(
        jnp.sin(attention_reference(q, k, v, causal=True, window=window,
                                    softcap=softcap))
    )
    np.testing.assert_allclose(f_new(q, k, v), f_ref(q, k, v), rtol=1e-5)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_new, g_ref):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU Pallas kernel (interpret) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,w", [(2, 64, 128), (1, 128, 256), (3, 33, 128)])
def test_rglru_pallas_vs_ref(b, s, w, dtype):
    key = jax.random.PRNGKey(3)
    bt = jax.random.normal(key, (b, s, w)).astype(dtype)
    a = jax.random.uniform(jax.random.fold_in(key, 1), (b, s, w),
                           minval=0.1, maxval=0.95).astype(dtype)
    h0 = jax.random.normal(jax.random.fold_in(key, 2), (b, w)).astype(dtype)
    got_y, got_h = rglru_scan_pallas(bt, a, h0, interpret=True)
    want_y, want_h = rglru_scan_reference(bt, a, h0)
    tol = TOL[dtype] * 10  # sequential accumulation over s steps
    np.testing.assert_allclose(got_y.astype(jnp.float32),
                               want_y.astype(jnp.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(got_h.astype(jnp.float32),
                               want_h.astype(jnp.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# RWKV-6 Pallas kernel (interpret) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,d", [(2, 32, 2, 16), (1, 64, 4, 32)])
def test_rwkv6_pallas_vs_ref(b, s, h, d):
    key = jax.random.PRNGKey(4)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (b, s, h, d))
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(mk(3)) * 0.9 + 0.05      # decay in (0, 1)
    u = jax.random.normal(jax.random.fold_in(key, 5), (h, d))
    s0 = jax.random.normal(jax.random.fold_in(key, 6), (b, h, d, d))
    # pallas kernel runs on [B*H, S, D]-flattened operands
    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d)
    s0f = s0.reshape(b * h, d, d)
    got_y, got_s = rwkv6_pallas(
        flat(r), flat(k), flat(v), flat(w), uf, s0f,
        chunk=16, interpret=True,
    )
    want_y, want_s = rwkv6_reference(r, k, v, w, u, s0)
    want_yf = flat(want_y)
    want_sf = want_s.reshape(b * h, d, d)
    np.testing.assert_allclose(got_y, want_yf, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got_s, want_sf, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ops-level dispatchers (associative scan path, chunked rwkv path)
# ---------------------------------------------------------------------------
def test_rglru_ops_associative_matches_ref():
    key = jax.random.PRNGKey(7)
    bt = jax.random.normal(key, (2, 48, 64))
    a = jax.random.uniform(jax.random.fold_in(key, 1), (2, 48, 64),
                           minval=0.1, maxval=0.95)
    h0 = jax.random.normal(jax.random.fold_in(key, 2), (2, 64))
    got_y, got_h = rglru_scan(bt, a, h0, impl="associative")
    want_y, want_h = rglru_scan_reference(bt, a, h0)
    np.testing.assert_allclose(got_y, want_y, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(got_h, want_h, atol=1e-5, rtol=1e-5)


def test_rwkv6_ops_chunked_matches_ref():
    key = jax.random.PRNGKey(8)
    b, s, h, d = 2, 32, 2, 16
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (b, s, h, d))
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(mk(3)) * 0.9 + 0.05
    u = jax.random.normal(jax.random.fold_in(key, 5), (h, d))
    got_y, got_s = rwkv6_mix(r, k, v, w, u, None, impl="chunked")
    want_y, want_s = rwkv6_reference(r, k, v, w, u, None)
    np.testing.assert_allclose(got_y, want_y, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got_s, want_s, atol=1e-4, rtol=1e-4)
