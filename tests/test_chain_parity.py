"""Per-link ring-chain execution (DESIGN.md §14).

Covers the whole stack of the heterogeneous-link runtime:

* ``launch.mesh.ring_chain`` — link 0 is the natural order, link l > 0
  a stride interleave that is a genuine permutation (distinct wires);
* ``train.chains.chain_perm`` — the ppermute permutations a chain
  compiles to;
* ``RuntimeConfig.secondary_chain`` — construction-time validation
  (permutation check, engine compatibility, mesh-width agreement);
* the 4-device subprocess: chain reduce-scatter / all-gather /
  all-reduce are BITWISE-equal to the single-axis collectives they
  replace (including an int8-wire secondary-synced bucket and
  chain-routed streamed AGs on the sharded flat engine), and a jaxpr
  census proves the secondary traffic runs on the chain's permutations
  — i.e. it genuinely left the primary ring's device order.

The bitwise contract is the point: the Preserver gate reasons about
schedule noise, not link noise, so training must be bit-identical
whichever link a bucket rides.
"""
import os
import pathlib
import subprocess
import sys

import jax
import pytest

from repro.core.links import LinkModel, effective_mu
from repro.launch.mesh import link_chains, ring_chain
from repro.train.chains import chain_perm
from repro.train.runtime import RuntimeConfig


# ---------------------------------------------------------------------------
# chains: topology-side properties
# ---------------------------------------------------------------------------
def test_ring_chain_link0_is_natural_order():
    for n in (1, 2, 3, 4, 8, 16):
        assert ring_chain(n, 0) == tuple(range(n))


@pytest.mark.parametrize("n", [2, 3, 4, 5, 8, 16, 17])
@pytest.mark.parametrize("link", [0, 1, 2, 3])
def test_ring_chain_is_permutation(n, link):
    chain = ring_chain(n, link)
    assert sorted(chain) == list(range(n))


def test_ring_chain_secondary_is_distinct():
    """Link 1 must use genuinely different neighbor pairs than link 0 —
    same ring order would contend for the same wires."""
    assert ring_chain(4, 1) == (0, 2, 1, 3)
    for n in (3, 4, 8, 16):
        natural = set(chain_perm(ring_chain(n, 0)))
        second = set(chain_perm(ring_chain(n, 1)))
        assert natural != second, n


def test_link_chains_covers_all_links():
    chains = link_chains(8, n_links=3)
    assert set(chains) == {0, 1, 2}
    assert chains[0] == tuple(range(8))
    assert all(sorted(c) == list(range(8)) for c in chains.values())


def test_chain_perm_pairs():
    assert chain_perm((0, 2, 1, 3), jump=1) == (
        (0, 2), (2, 1), (1, 3), (3, 0)
    )
    # jump-s rounds of the RS: every device both sends and receives once
    for s in (1, 2, 3):
        perm = chain_perm((0, 2, 1, 3), jump=s)
        assert sorted(p[0] for p in perm) == [0, 1, 2, 3]
        assert sorted(p[1] for p in perm) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# link models (pricing side)
# ---------------------------------------------------------------------------
def test_link_model_pricing_identities():
    lm = LinkModel(latency=0.002, inv_bw=1.65)
    assert lm.time(0.0) == 0.0              # nothing to send, no latency
    assert lm.time(0.1) == 0.002 + 0.1 * 1.65
    pair = LinkModel.pair_from_mu(1.65)
    # legacy scalar-mu pricing, byte-identical: no latency term
    assert pair[0].time(0.25) == 0.25
    assert pair[1].time(0.25) == 0.25 * 1.65
    assert effective_mu(pair) == 1.65


# ---------------------------------------------------------------------------
# RuntimeConfig validation
# ---------------------------------------------------------------------------
def test_config_chain_normalizes_and_hashes():
    c = RuntimeConfig(secondary_chain=[0, 2, 1, 3])
    assert c.secondary_chain == (0, 2, 1, 3)
    hash(c)  # frozen + tuple: usable as a cache-key component


def test_config_chain_must_be_permutation():
    with pytest.raises(ValueError, match="permutation"):
        RuntimeConfig(secondary_chain=(0, 2, 2, 3))
    with pytest.raises(ValueError, match="permutation"):
        RuntimeConfig(secondary_chain=(1, 2, 3, 4))


def test_config_chain_refuses_tree_state_rs_engine():
    with pytest.raises(ValueError, match="tree-state"):
        RuntimeConfig(fsdp=True, flat_state=False,
                      secondary_chain=(0, 2, 1, 3))


def test_config_chain_refuses_replicated_multi_pod():
    # the replicated engines sync with ONE joint ('pod','data') psum —
    # a per-axis chain cannot reproduce that reduction order bitwise
    with pytest.raises(ValueError, match="multi-pod"):
        RuntimeConfig(multi_pod=True, secondary_chain=(0, 2, 1, 3))
    # the sharded flat engine's shard-axis RS is separate from the pod
    # all-reduce, so the chain composes there
    c = RuntimeConfig(multi_pod=True, fsdp=True,
                      secondary_chain=(0, 2, 1, 3))
    assert c.sharded_flat


def test_runtime_refuses_chain_mesh_mismatch(single_mesh):
    """A chain built for the wrong data-axis width is refused at
    construction, not deep inside shard_map."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.optim.optimizers import adamw
    from repro.train import DeftRuntime, init_train_state
    from repro.train.bucketing import build_bucket_layout
    from test_train_steps import _schedule_for

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    probe = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    bucket_of, nb, sched = _schedule_for(cfg, probe["params"], cr=0.5)
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    with pytest.raises(ValueError, match="data' axis"):
        DeftRuntime(
            cfg, opt, sched, layout, single_mesh,
            config=RuntimeConfig(secondary_chain=ring_chain(4, 1)),
        )


# ---------------------------------------------------------------------------
# 4-device bitwise parity + jaxpr census (forced host devices, subprocess)
# ---------------------------------------------------------------------------
_SCRIPT = r"""
import dataclasses
import functools
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import Planner, PlanRequest
from repro.core.precision import PrecisionPolicy
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.launch.mesh import ring_chain
from repro.optim.optimizers import adamw
from repro.train import (DeftRuntime, RuntimeConfig, assign_buckets,
                         build_bucket_layout, init_train_state,
                         leaf_bucket_times)
from repro.train.chains import (chain_all_gather, chain_all_reduce,
                                chain_perm, chain_reduce_scatter)

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
CHAIN = ring_chain(4, 1)
assert CHAIN == (0, 2, 1, 3)

# ---- part A: raw chain collectives are bitwise-equal -----------------------
def body_eq(x):
    # device-distinct values: scale by (axis index + 1)
    v = x * (jax.lax.axis_index("data") + 1.0)
    rs_c = chain_reduce_scatter(v, "data", CHAIN)
    rs_x = jax.lax.psum_scatter(v, "data", scatter_dimension=0, tiled=True)
    ag_c = chain_all_gather(rs_x, "data", CHAIN)
    ag_x = jax.lax.all_gather(rs_x, "data", axis=0, tiled=True)
    w = v[:1021]  # non-divisible size exercises the AR padding
    ar_c = chain_all_reduce(w, "data", CHAIN)
    ar_x = jax.lax.psum(w, "data")
    flags = jnp.stack([
        jnp.all(rs_c == rs_x), jnp.all(ag_c == ag_x), jnp.all(ar_c == ar_x),
    ]).astype(jnp.int32)
    return jax.lax.psum(flags, "data")

X = jax.random.normal(jax.random.PRNGKey(7), (4096,), jnp.float32)
with jax.set_mesh(mesh):
    flags = jax.jit(jax.shard_map(
        body_eq, mesh=mesh, in_specs=P(), out_specs=P(),
        axis_names={"data"}, check_vma=False,
    ))(X)
assert [int(f) for f in flags] == [4, 4, 4], flags
print("raw chain collectives bitwise-equal")

# ---- part B: runtime parity on the sharded flat engine ---------------------
cfg = reduce_for_smoke(get_config("qwen3-4b"))
opt = adamw(1e-3)
key = jax.random.PRNGKey(0)
probe = init_train_state(key, cfg, opt)
bucket_of, nb = assign_buckets(probe["params"], cfg, partition_elems=150_000)
B, S = 8, 32
times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                          HardwareModel(dp_degree=4), S, 2)
scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
times = BucketTimes(times.fwd, times.bwd, tuple(c * scale for c in times.comm))
WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
res = Planner().plan(PlanRequest(times=times, walk=WALK, decoupled=True))
sched = res.schedule

# force every synced bucket onto the secondary link and every streamed AG
# onto it too — maximal chain routing, deterministic regardless of what
# the knapsack picked for this profile (routing must not change values)
phases = []
for ph in sched.phases:
    sec = tuple(
        (ph.route_new[b] == "sync" and ph.rotate) or ph.sync_cur[b]
        for b in range(len(ph.route_new))
    )
    phases.append(dataclasses.replace(ph, secondary=sec))
sched = dataclasses.replace(sched, phases=tuple(phases))
assert any(any(ph.secondary) for ph in sched.phases)
ag_plan = dataclasses.replace(
    res.ag_plan,
    items=tuple(dataclasses.replace(i, link=1) for i in res.ag_plan.items),
)
assert ag_plan.items, "decoupled plan must stream gathers"

lay = build_bucket_layout(probe["params"], bucket_of, nb, shard_count=4)
# int8 wire on bucket 0: the quantize edge must compose with the chain
pol = PrecisionPolicy(wire=("int8",) + ("f32",) * (nb - 1))
lay = lay.with_precision(pol)
sec0 = any(ph.secondary[0] for ph in sched.phases)
assert sec0, "bucket 0 must be secondary-synced somewhere in the cycle"

base = RuntimeConfig(fsdp=True, decoupled=True)
rt_p = DeftRuntime(cfg, opt, sched, lay, mesh, config=base)
rt_c = DeftRuntime(cfg, opt, sched, lay, mesh,
                   config=base.replace(secondary_chain=CHAIN),
                   ag_plan=ag_plan)
sp = rt_p.init_state(key)
sc = rt_c.init_state(key)
with jax.set_mesh(mesh):
    for i in range(sched.period + 1):
        b = make_batch(cfg, 0, i, B, S)
        sp, mp = rt_p.step(i, sp, b)
        sc, mc = rt_c.step(i, sc, b)
        assert float(mp["loss"]) == float(mc["loss"]), (
            i, float(mp["loss"]), float(mc["loss"]))
    for a, c in zip(sp["pbuf"], sc["pbuf"]):
        assert bool(jnp.array_equal(a, c)), "pbuf diverged across links"
print("runtime chain parity bitwise (losses + pbuf), int8 bucket included")

# ---- part C: jaxpr census — the chain is really on the wire ----------------
def subjaxprs(p):
    if hasattr(p, "eqns"):          # a raw Jaxpr
        return [p]
    if hasattr(p, "jaxpr"):         # a ClosedJaxpr
        return [p.jaxpr]
    if isinstance(p, (list, tuple)):
        return [j for x in p for j in subjaxprs(x)]
    return []

def perms_of(jaxpr, out):
    for eq in jaxpr.eqns:
        if eq.primitive.name == "ppermute":
            out.append(tuple(map(tuple, eq.params["perm"])))
        for p in eq.params.values():
            for sub in subjaxprs(p):
                perms_of(sub, out)
    return out

off = next(t for t, ph in enumerate(sched.phases) if any(ph.secondary))
key_c = rt_c._schedule_keys(sched)[off]
jitted = rt_c._entries[key_c].jitted
b0 = make_batch(cfg, 0, 0, B, S)
with jax.set_mesh(mesh):
    jaxpr = jax.make_jaxpr(jitted)(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sc), b0).jaxpr
perms = perms_of(jaxpr, [])
assert perms, "chain runtime emitted no ppermute"
allowed = {chain_perm(CHAIN, jump=s) for s in (1, 2, 3)}
natural = {chain_perm(tuple(range(4)), jump=s) for s in (1, 2, 3)}
got = set(perms)
assert got <= allowed, f"unexpected perms: {got - allowed}"
assert not (got & natural), "secondary traffic still on the natural ring"

# the no-chain runtime must emit NO ppermute at all
key_p = rt_p._schedule_keys(sched)[off]
with jax.set_mesh(mesh):
    jaxpr_p = jax.make_jaxpr(rt_p._entries[key_p].jitted)(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sp), b0).jaxpr
assert not perms_of(jaxpr_p, []), "chainless runtime emitted ppermute"
print(f"jaxpr census: {len(perms)} ppermutes, all on chain {CHAIN}")
print("CHAIN_PARITY_4DEV_OK")
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_chain_parity_on_4_devices(tmp_path):
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script), src],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "CHAIN_PARITY_4DEV_OK" in out.stdout
