"""Data pipeline determinism + checkpoint roundtrip + config registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import restore, save
from repro.configs import (
    ARCH_NAMES,
    SHAPES,
    get_config,
    config_for_shape,
    reduce_for_smoke,
)
from repro.data.pipeline import SyntheticDataset, make_batch
from repro.models.model import init_params


def test_make_batch_deterministic():
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    b1 = make_batch(cfg, seed=7, step=3, batch=4, seq_len=16)
    b2 = make_batch(cfg, seed=7, step=3, batch=4, seq_len=16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, seed=7, step=4, batch=4, seq_len=16)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    b4 = make_batch(cfg, seed=8, step=3, batch=4, seq_len=16)
    assert not np.array_equal(b1["tokens"], b4["tokens"])


def test_dataset_iterator_advances():
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    ds = SyntheticDataset(cfg, seed=0, batch=2, seq_len=8)
    a = next(ds)
    b = next(ds)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_batch_tokens_learnable_structure():
    """The synthetic stream is Markov-ish: a model can beat the unigram
    entropy, so convergence tests actually converge.  Check that the
    bigram distribution is far from independent."""
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    b = make_batch(cfg, seed=0, step=0, batch=8, seq_len=256)
    toks = np.asarray(b["tokens"]).reshape(-1)
    pairs = {}
    for x, y in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(x), []).append(int(y))
    # for tokens with >=8 successors, the mode should be overrepresented
    frac = []
    for x, ys in pairs.items():
        if len(ys) >= 8:
            vals, counts = np.unique(ys, return_counts=True)
            frac.append(counts.max() / len(ys))
    assert np.mean(frac) > 0.3


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "step": jnp.asarray(17)}
    save(str(tmp_path), 17, state)
    back = restore(str(tmp_path), 17, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_registry_complete():
    assert len(ARCH_NAMES) == 10
    assert len(SHAPES) == 4
    for name in ARCH_NAMES:
        cfg = get_config(name)
        assert cfg.total_params() > 0
        smoke = reduce_for_smoke(cfg)
        assert smoke.d_model <= 512
        assert smoke.n_layers <= 3
        if smoke.moe:
            assert smoke.moe.n_experts <= 4


def test_assigned_config_numbers():
    """Spot-check the assigned architecture table."""
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (60, 5120, 128, 102400)
    assert c.moe.n_experts == 160 and c.moe.experts_per_token == 6
    assert c.mla.kv_lora_rank == 512
    c = get_config("gemma2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (
        26, 2304, 8, 4, 9216)
    assert c.vocab_size == 256000 and c.attn_logit_softcap > 0
    c = get_config("llama4-maverick-400b-a17b")
    assert c.moe.n_experts == 128 and c.moe.experts_per_token == 1
    c = get_config("rwkv6-1.6b")
    assert c.n_layers == 24 and c.d_model == 2048 and c.vocab_size == 65536
    c = get_config("llama-3.2-vision-90b")
    assert c.n_layers == 100 and c.d_model == 8192
    c = get_config("seamless-m4t-large-v2")
    assert c.is_encoder_decoder and c.n_encoder_layers == 24


def test_long_context_applicability():
    runnable = {a for a in ARCH_NAMES
                if config_for_shape(a, "long_500k").supports_long_context()}
    # starcoder2 uses a native 4k sliding window on every layer, so its
    # ring cache is O(window) and 500k decode is runnable (DESIGN.md §4)
    assert runnable == {"recurrentgemma-9b", "rwkv6-1.6b", "gemma2-2b",
                        "starcoder2-7b"}
