"""Bucket construction / partition strategies (paper §III.D, Table II)."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_NAMES, get_config
from repro.core.bucket import (
    apply_deft_constraint,
    build_buckets,
    model_layer_elems,
    partition_uniform,
    partition_usbyte,
)


@given(
    st.lists(st.integers(min_value=1, max_value=10_000_000), min_size=1,
             max_size=40),
    st.integers(min_value=1, max_value=30_000_000),
)
@settings(max_examples=40, deadline=None)
def test_uniform_partition_covers_everything(elems, target):
    buckets = partition_uniform(elems, target)
    assert sum(b.n_elements for b in buckets) == sum(elems)
    covered = [lid for b in buckets for lid in b.layer_ids]
    assert covered == list(range(len(elems)))
    assert [b.index for b in buckets] == list(range(1, len(buckets) + 1))


@given(
    st.lists(st.integers(min_value=1, max_value=10_000_000), min_size=1,
             max_size=40),
    st.integers(min_value=100_000, max_value=30_000_000),
)
@settings(max_examples=40, deadline=None)
def test_usbyte_partition_covers_everything(elems, base):
    buckets = partition_usbyte(elems, base)
    assert sum(b.n_elements for b in buckets) == sum(elems)
    covered = [lid for b in buckets for lid in b.layer_ids]
    assert covered == list(range(len(elems)))


def test_deft_constraint_splits_oversized():
    elems = [50_000_000, 1_000_000]
    buckets = partition_uniform(elems, 100_000_000)  # one huge bucket
    comm = lambda n: n * 1e-9
    out = apply_deft_constraint(buckets, comm, max_comm_time=0.01)
    assert all(comm(b.n_elements) <= 0.0101 for b in out)
    assert sum(b.n_elements for b in out) == sum(elems)
    assert [b.index for b in out] == list(range(1, len(out) + 1))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_build_buckets_all_archs(arch):
    cfg = get_config(arch)
    total = sum(model_layer_elems(cfg))
    for strategy in ("uniform", "usbyte", "deft"):
        buckets = build_buckets(cfg, strategy=strategy)
        assert sum(b.n_elements for b in buckets) == total
        # paper: knapsack item counts stay small
        assert 1 <= len(buckets) < 400


def test_paper_default_bucket_sizes():
    """25 MB DDP default == 6,553,600 fp32 elements."""
    cfg = get_config("gemma2-2b")
    buckets = build_buckets(cfg, strategy="uniform",
                            partition_elems=6_553_600)
    big = [b for b in buckets if b.n_elements > 2 * 6_553_600]
    # uniform greedy fill may overshoot only on single giant layers
    layer_elems = model_layer_elems(cfg)
    assert all(
        any(layer_elems[lid] > 6_553_600 for lid in b.layer_ids) for b in big
    )
