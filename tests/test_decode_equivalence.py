"""Serving-path correctness: prefill + token-by-token decode must produce
the same logits as one full forward pass (per architecture family)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    prefill,
)

# one representative per family/mixer type (keeps CPU time sane)
FAMILIES = [
    "qwen3-4b",           # dense GQA + qk-norm
    "gemma2-2b",          # local+global alternating + softcaps + post-norms
    "deepseek-v2-236b",   # MLA + MoE
    "rwkv6-1.6b",         # rwkv recurrence
    "recurrentgemma-9b",  # rglru + local attention hybrid
    "seamless-m4t-large-v2",   # enc-dec cross attention
    "llama-3.2-vision-90b",    # gated cross-attention VLM
]


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_then_decode_matches_forward(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 24
    n_prefill = 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.modality != "text":
        memory = jax.random.normal(
            key, (B, max(cfg.n_modal_tokens, 1), cfg.d_model)
        )

    enc_mem = memory
    if cfg.is_encoder_decoder:
        enc_mem = encode(params, cfg, memory)

    # MoE expert-capacity dropping depends on how many tokens share a
    # dispatch (48-token forward vs 1-token decode) — a generous capacity
    # factor removes drops from both paths so they must agree exactly.
    cap = 16.0

    # ground truth: full forward over all S positions
    full_logits, _, _ = forward(params, cfg, tokens, memory=enc_mem,
                                capacity_factor=cap)

    # prefill the first n_prefill tokens, then decode the rest one by one
    cache = init_cache(cfg, B, S, prefill_chunk=n_prefill)
    last, cache = prefill(params, cfg, tokens[:, :n_prefill], cache,
                          memory=memory, capacity_factor=cap)
    got = [last]
    for i in range(n_prefill, S):
        logits, cache = decode_step(params, cfg, tokens[:, i],
                                    cache, jnp.asarray(i),
                                    capacity_factor=cap)
        got.append(logits)
    got = jnp.stack(got, axis=1)  # positions n_prefill-1 .. S-1

    want = full_logits[:, n_prefill - 1 :]
    err = float(jnp.max(jnp.abs(got - want)))
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    assert err / scale < 2e-4, f"{arch}: decode diverges from forward ({err})"


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: the ring cache must keep exactly the
    window and match a full forward."""
    cfg = reduce_for_smoke(get_config("gemma2-2b"))
    assert cfg.sliding_window
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B = 1
    S = cfg.sliding_window * 2 + 7   # well past the ring size
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, cfg, tokens)

    cache = init_cache(cfg, B, S, prefill_chunk=1)
    logits, cache = prefill(params, cfg, tokens[:, :1], cache)
    for i in range(1, S):
        logits, cache = decode_step(params, cfg, tokens[:, i], cache,
                                    jnp.asarray(i))
    err = float(jnp.max(jnp.abs(logits - full_logits[:, -1])))
    scale = float(jnp.max(jnp.abs(full_logits[:, -1]))) + 1e-6
    assert err / scale < 2e-4
