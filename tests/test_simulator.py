"""Discrete-event simulator invariants + paper-level behaviour checks."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bucket import BucketTimes
from repro.core.policies import ALL_BASELINES, bytescheduler, pytorch_ddp, usbyte
from repro.core.scheduler import DeftScheduler, SchedulerConfig
from repro.core.simulator import simulate_baseline, simulate_deft


def make_times(fwd, bwd, comm):
    return BucketTimes(tuple(fwd), tuple(bwd), tuple(comm))


times_strategy = st.integers(min_value=2, max_value=8).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(0.001, 0.1), min_size=n, max_size=n),
        st.lists(st.floats(0.001, 0.2), min_size=n, max_size=n),
        st.lists(st.floats(0.001, 0.4), min_size=n, max_size=n),
    )
)


@given(times_strategy)
@settings(max_examples=25, deadline=None)
def test_iteration_time_lower_bound(t):
    """No schedule beats pure compute time; bubbles are in [0, 1]."""
    times = make_times(*t)
    compute = times.fwd_total + times.bwd_total
    for name, mk in ALL_BASELINES.items():
        r = simulate_baseline(times, mk(times))
        assert r.iteration_time >= compute - 1e-9, name
        assert 0.0 <= r.bubble_fraction < 1.0
    plans = DeftScheduler(times, SchedulerConfig()).run(24)
    r = simulate_deft(times, plans)
    assert r.iteration_time >= compute - 1e-9
    assert 0.0 <= r.bubble_fraction < 1.0


@given(times_strategy)
@settings(max_examples=25, deadline=None)
def test_timeline_streams_serial(t):
    """Within each stream (compute, link), intervals must not overlap."""
    times = make_times(*t)
    r = simulate_baseline(times, usbyte(times), keep_timeline=True)
    by_stream = {}
    for stream, s, e, _ in r.timeline:
        by_stream.setdefault(stream, []).append((s, e))
    for stream, spans in by_stream.items():
        spans.sort()
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert e1 <= s2 + 1e-9, f"overlap in {stream}"


def test_ddp_slowest_when_comm_heavy():
    """Paper Fig. 10: overlap-aware schedulers beat blocking DDP when
    communication is significant."""
    times = make_times([0.01] * 6, [0.02] * 6, [0.06] * 6)
    r_ddp = simulate_baseline(times, pytorch_ddp(times))
    r_bs = simulate_baseline(times, bytescheduler(times))
    assert r_bs.iteration_time <= r_ddp.iteration_time + 1e-9


def test_deft_beats_baselines_at_high_cr():
    """The paper's headline: with CR > 1, DeFT's delayed updates eliminate
    the bubbles the baselines cannot."""
    times = make_times([0.02] * 6, [0.04] * 6, [0.13] * 6)
    assert times.coverage_rate > 1.5
    plans = DeftScheduler(times, SchedulerConfig()).run(32)
    r_deft = simulate_deft(times, plans)
    for name, mk in ALL_BASELINES.items():
        r = simulate_baseline(times, mk(times))
        assert r_deft.iteration_time <= r.iteration_time + 1e-9, name
    # near-zero bubbles (the knapsack covered everything it scheduled)
    assert r_deft.bubble_fraction < 0.25


def test_deft_low_cr_keeps_full_update_frequency():
    times = make_times([0.05] * 4, [0.1] * 4, [0.01] * 4)
    plans = DeftScheduler(times, SchedulerConfig()).run(24)
    r = simulate_deft(times, plans)
    assert r.updates_per_iteration == pytest.approx(1.0)


def test_speedup_reported_vs_other():
    times = make_times([0.02] * 5, [0.04] * 5, [0.12] * 5)
    r1 = simulate_baseline(times, pytorch_ddp(times))
    plans = DeftScheduler(times, SchedulerConfig()).run(24)
    r2 = simulate_deft(times, plans)
    assert r2.throughput_speedup_vs(r1) >= 1.0
