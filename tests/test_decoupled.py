"""Decoupled-collective streaming schedule (DESIGN.md §12).

Covers the whole stack of the RS/AG split:

* item model — ``rs_times``/``ag_times`` conserve wire time,
  ``ag_deadlines`` are forward prefixes;
* ``deadline_knapsack`` — EDF feasibility, the memo-key-includes-
  deadlines regression;
* ``plan_ag_stream`` — gather-skip composition (stale cycle positions
  emit no AG items), coverage accounting;
* simulator — streamed never slower than burst, a hopeless deadline
  prices a forward stall;
* Planner facade — shim equivalence, ``decoupled=True`` attaches an
  ``AgStreamPlan`` solved on the RS-side profile;
* RuntimeConfig — construction-time validation, config/legacy-kwarg
  exclusivity, ``spawn`` inheritance via ``config.replace``;
* the engine — bit-identical training vs the fused path, and a jaxpr
  census proving the per-bucket all-gathers stream into the forward
  instead of bursting at phase start (the 4-device run lives in the
  ``multidevice`` suite).
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import (
    Planner,
    PlanRequest,
    ag_deadlines,
    ag_times,
    feedback_solve,
    plan_ag_stream,
    rs_times,
)
from repro.core.knapsack import deadline_knapsack
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.core.scheduler import DeftScheduler, SchedulerConfig
from repro.core.simulator import simulate_deft
from repro.data.pipeline import make_batch
from repro.models.model import init_params
from repro.optim.optimizers import adamw
from repro.train import (
    DeftRuntime,
    RuntimeConfig,
    assign_buckets,
    build_bucket_layout,
    leaf_bucket_times,
)

WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)


def make_times(fwd, bwd, comm):
    return BucketTimes(tuple(fwd), tuple(bwd), tuple(comm))


times_strategy = st.integers(min_value=2, max_value=8).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(0.001, 0.1), min_size=n, max_size=n),
        st.lists(st.floats(0.001, 0.2), min_size=n, max_size=n),
        st.lists(st.floats(0.001, 0.4), min_size=n, max_size=n),
    )
)


# ---------------------------------------------------------------------------
# item model
# ---------------------------------------------------------------------------
def test_split_conserves_wire_time():
    t = make_times([0.01] * 4, [0.02] * 4, [0.05, 0.07, 0.03, 0.09])
    for frac in (0.0, 0.3, 0.5, 1.0):
        ag = ag_times(t, frac)
        rs = rs_times(t, frac)
        for b in range(t.n):
            assert ag[b] + rs.comm[b] == pytest.approx(t.comm[b])
        assert rs.fwd == t.fwd and rs.bwd == t.bwd
    with pytest.raises(ValueError):
        ag_times(t, 1.5)
    with pytest.raises(ValueError):
        rs_times(t, -0.1)


def test_ag_deadlines_are_forward_prefixes():
    t = make_times([0.01, 0.02, 0.03], [0.1] * 3, [0.1] * 3)
    assert ag_deadlines(t) == pytest.approx((0.0, 0.01, 0.03))


# ---------------------------------------------------------------------------
# deadline knapsack
# ---------------------------------------------------------------------------
def test_deadline_knapsack_respects_deadlines():
    # bucket 0 is consumed at forward start (deadline 0): never coverable
    w = [0.05, 0.05, 0.05]
    d = [0.0, 0.06, 0.2]
    sel = deadline_knapsack(w, d, capacity=1.0)
    assert 0 not in sel
    # selected items, transmitted EDF from t=0, all finish by deadline
    order = sorted(sel, key=lambda i: d[i])
    t = 0.0
    for i in order:
        t += w[i]
        assert t <= d[i] + 1e-9


def test_deadline_knapsack_maximises_covered_time():
    # greedy-by-deadline would take item 0 (d=0.05) and lose items 1+2;
    # the DP must find the {1, 2} placement instead
    w = [0.05, 0.04, 0.04]
    d = [0.05, 0.04, 0.08]
    sel = set(deadline_knapsack(w, d, capacity=1.0))
    assert sel == {1, 2}


def test_deadline_knapsack_memo_distinguishes_deadlines():
    """Regression: the memo key must include the deadline tuple — two
    instances identical except for deadlines are different problems."""
    w = [0.05, 0.05]
    loose = deadline_knapsack(w, [1.0, 1.0], capacity=1.0)
    tight = deadline_knapsack(w, [0.0, 0.0], capacity=1.0)
    assert set(loose) == {0, 1}
    assert tight == []
    # and ask the loose instance again — the cache must still say {0, 1}
    assert set(deadline_knapsack(w, [1.0, 1.0], capacity=1.0)) == {0, 1}


def test_deadline_knapsack_capacity_binds():
    w = [0.4, 0.4, 0.4]
    d = [10.0, 10.0, 10.0]
    sel = deadline_knapsack(w, d, capacity=0.9)
    assert len(sel) == 2


# ---------------------------------------------------------------------------
# plan_ag_stream
# ---------------------------------------------------------------------------
def _merging_schedule(n=6, cr=2.5):
    t = make_times([0.02] * n, [0.03] * n, [c * cr * 0.05 / 1.0 for c in [1] * n])
    sched, _, scfg, _ = feedback_solve(t, WALK)
    return t, sched, scfg


def test_plan_ag_stream_gather_skip_composition():
    t, sched, scfg = _merging_schedule()
    plan = plan_ag_stream(sched, t, scfg, gather_skip=True)
    fresh = {
        tt for tt in range(sched.period)
        if tt == 0 or sched.phases[tt - 1].do_update
    }
    phases_with_items = {i.phase for i in plan.items}
    assert phases_with_items == fresh
    # stale positions are served from the replicated cache: no items
    for tt in range(sched.period):
        n_items = len(plan.items_for_phase(tt))
        assert n_items == (t.n if tt in fresh else 0)
    # without gather-skip every position gathers every bucket
    plan_all = plan_ag_stream(sched, t, scfg, gather_skip=False)
    assert {i.phase for i in plan_all.items} == set(range(sched.period))
    assert len(plan_all.items) == sched.period * t.n
    assert plan_all.total_s >= plan.total_s


def test_plan_ag_stream_coverage_accounting():
    t, sched, scfg = _merging_schedule()
    plan = plan_ag_stream(sched, t, scfg)
    assert 0.0 <= plan.coverage <= 1.0
    assert plan.covered_s == pytest.approx(
        sum(i.duration for i in plan.items if i.covered))
    # every covered item actually meets its deadline on its link when
    # transmitted EDF within its phase
    for tt in range(sched.period):
        for link in (0, 1):
            clock = 0.0
            items = sorted(
                (i for i in plan.items_for_phase(tt)
                 if i.covered and i.link == link),
                key=lambda i: i.deadline,
            )
            mu = scfg.mu if link == 1 else 1.0
            for i in items:
                clock += i.duration * mu
                assert clock <= i.deadline + 1e-9


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------
@given(times_strategy)
@settings(max_examples=20, deadline=None)
def test_streamed_never_slower_than_burst(t):
    times = make_times(*t)
    plans = DeftScheduler(times, SchedulerConfig()).run(24)
    ag = ag_times(times)
    r_s = simulate_deft(times, plans, ag_times=ag, ag_mode="streamed")
    r_b = simulate_deft(times, plans, ag_times=ag, ag_mode="burst")
    assert r_s.iteration_time <= r_b.iteration_time + 1e-9
    assert r_s.ag_stall_s >= 0.0 and r_b.ag_stall_s >= 0.0


def test_hopeless_deadline_prices_a_stall():
    """Bucket 0's AG can never beat its deadline (forward-prefix 0), so
    streaming must charge a stall — and a big AG must slow iteration."""
    times = make_times([0.01] * 4, [0.02] * 4, [0.01] * 4)
    plans = DeftScheduler(times, SchedulerConfig()).run(24)
    base = simulate_deft(times, plans)
    big_ag = [0.5, 0.0, 0.0, 0.0]
    r = simulate_deft(times, plans, ag_times=big_ag, ag_mode="streamed")
    assert r.ag_stall_s > 0.0
    assert r.iteration_time > base.iteration_time


def test_ag_skip_reduces_traffic():
    times = make_times([0.01] * 4, [0.02] * 4, [0.08] * 4)
    plans = DeftScheduler(times, SchedulerConfig()).run(24)
    ag = ag_times(times)
    r_skip = simulate_deft(times, plans, ag_times=ag, ag_skip=True)
    r_all = simulate_deft(times, plans, ag_times=ag, ag_skip=False)
    assert r_skip.iteration_time <= r_all.iteration_time + 1e-9


# ---------------------------------------------------------------------------
# Planner facade
# ---------------------------------------------------------------------------
def test_planner_times_path_matches_shim():
    t = make_times([0.02] * 5, [0.03] * 5, [0.12] * 5)
    sched_s, verdict_s, scfg_s, retries_s = feedback_solve(t, WALK)
    res = Planner().plan(PlanRequest(times=t, walk=WALK))
    assert res.schedule == sched_s
    assert res.verdict == verdict_s
    assert res.scheduler_cfg == scfg_s
    assert res.retries == retries_s
    assert res.ok and res.ag_plan is None


def test_planner_decoupled_attaches_ag_plan():
    t = make_times([0.02] * 5, [0.03] * 5, [0.12] * 5)
    res = Planner().plan(PlanRequest(times=t, walk=WALK, decoupled=True))
    assert res.ag_plan is not None
    assert res.ag_plan.period == res.schedule.period
    # the schedule was solved on the RS-side profile
    rs_only = Planner().plan(
        PlanRequest(times=rs_times(t), walk=WALK))
    assert res.schedule == rs_only.schedule
    # AG durations in the plan price the split-off half
    split = ag_times(t)
    for item in res.ag_plan.items:
        assert item.duration == pytest.approx(split[item.bucket])


def test_candidate_scoring_prices_ag_items_on_planned_links():
    """Regression: ``_plan_candidates`` used to call ``simulate_deft``
    without ``ag_links`` — every gather priced on the primary link even
    when the AG plan had off-loaded it to the secondary.  These two toy
    candidates are a concrete flip: under honest per-link pricing ``a``
    wins, under primary-only pricing ``b`` would — so a planner that
    drops the links picks the wrong partition."""
    import random

    def toy(cr, seed, n=8):
        rng = random.Random(seed)
        fwd = tuple(rng.uniform(0.002, 0.02) for _ in range(n))
        bwd = tuple(2 * f for f in fwd)
        comm = tuple(rng.uniform(0.005, 0.08) for _ in range(n))
        t = BucketTimes(fwd, bwd, comm)
        s = cr * (t.fwd_total + t.bwd_total) / t.comm_total
        return BucketTimes(fwd, bwd, tuple(c * s for c in comm))

    A, B = toy(1.8, seed=1), toy(2.2, seed=4)
    req = PlanRequest(candidates=(("a", A), ("b", B)), walk=WALK,
                      decoupled=True, sim_iterations=48)
    planner = Planner()

    def scores(zero_links: bool):
        out = {}
        for tag, times in req.candidates:
            solve_on = rs_times(times, req.ag_fraction)
            schedule, _, scfg, _ = planner._solve_times(solve_on, req)
            kw = planner._ag_sim_kwargs(schedule, times, scfg, req)
            assert kw and any(kw["ag_links"]), (
                "precondition: the AG plan must place items on link 1")
            if zero_links:
                kw = dict(kw, ag_links=tuple(0 for _ in kw["ag_links"]))
            sim = simulate_deft(
                solve_on,
                DeftScheduler(solve_on, scfg).run(req.sim_iterations),
                mu=scfg.mu, heterogeneous=scfg.heterogeneous,
                link_models=scfg.link_models, **kw,
            )
            out[tag] = sim.iteration_time
        return out

    honest, blind = scores(False), scores(True)
    # the flip precondition: per-link pricing and primary-only pricing
    # disagree on the ranking of this pair
    assert honest["a"] < honest["b"]
    assert blind["b"] < blind["a"]
    # and the real planner agrees with the honest ranking
    res = Planner().plan(req)
    assert res.winner_tag == "a"
    by_tag = {s.tag: s.iteration_time for s in res.candidates}
    for tag in ("a", "b"):
        assert by_tag[tag] == pytest.approx(honest[tag])


def test_planner_default_walk_used_when_request_has_none():
    t = make_times([0.02] * 4, [0.03] * 4, [0.1] * 4)
    res = Planner(walk=WALK).plan(PlanRequest(times=t))
    res2 = Planner().plan(PlanRequest(times=t, walk=WALK))
    assert res.schedule == res2.schedule


# ---------------------------------------------------------------------------
# RuntimeConfig validation
# ---------------------------------------------------------------------------
def test_runtime_config_validation():
    RuntimeConfig(fsdp=True, decoupled=True)          # legal
    RuntimeConfig(fsdp=True, gather_skip=True)        # legal
    with pytest.raises(ValueError, match="loss_chunk"):
        RuntimeConfig(loss_chunk=-1)
    with pytest.raises(ValueError, match="gather_skip"):
        RuntimeConfig(gather_skip=True)               # needs sharded flat
    with pytest.raises(ValueError, match="decoupled"):
        RuntimeConfig(decoupled=True)                 # needs sharded flat
    with pytest.raises(ValueError, match="decoupled"):
        RuntimeConfig(fsdp=True, flat_state=False, decoupled=True)
    with pytest.raises(ValueError, match="compute_dtype"):
        RuntimeConfig(flat_state=False, compute_dtype=jnp.bfloat16)


def test_runtime_config_replace():
    cfg = RuntimeConfig(fsdp=True, decoupled=True)
    off = cfg.replace(decoupled=False)
    assert off.fsdp and not off.decoupled
    with pytest.raises(ValueError, match="decoupled"):
        cfg.replace(fsdp=False)                       # re-validates


def _tiny_runtime_parts():
    base = get_config("qwen3-4b")
    cfg = dataclasses.replace(
        base, name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    bucket_of, nb = assign_buckets(params, cfg, partition_elems=40_000)
    t = leaf_bucket_times(params, cfg, bucket_of, nb,
                          HardwareModel(dp_degree=2), 32, 4)
    scale = 1.8 * (t.fwd_total + t.bwd_total) / t.comm_total
    t = BucketTimes(t.fwd, t.bwd, tuple(c * scale for c in t.comm))
    sched, _, _, _ = feedback_solve(t, WALK)
    lay = build_bucket_layout(params, bucket_of, nb, shard_count=1)
    return cfg, adamw(3e-4), sched, lay


def test_runtime_rejects_config_plus_legacy_kwargs(single_mesh):
    cfg, opt, sched, lay = _tiny_runtime_parts()
    with pytest.raises(ValueError, match="not both"):
        DeftRuntime(cfg, opt, sched, lay, single_mesh,
                    config=RuntimeConfig(fsdp=True), fsdp=True)
    # either style alone is fine, and they agree
    rt_a = DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True)
    rt_b = DeftRuntime(cfg, opt, sched, lay, single_mesh,
                       config=RuntimeConfig(fsdp=True))
    assert rt_a.config == rt_b.config
    assert rt_a.stats()["decoupled"] is False


def test_spawn_inherits_and_rescopes_config(single_mesh):
    cfg, opt, sched, lay = _tiny_runtime_parts()
    rt = DeftRuntime(cfg, opt, sched, lay, single_mesh,
                     config=RuntimeConfig(fsdp=True, decoupled=True))
    child = rt.spawn()
    assert child.config.decoupled and child.config.fsdp
    # decoupled cannot survive losing the sharded flat engine
    child2 = rt.spawn(fsdp=False)
    assert not child2.config.decoupled
    with pytest.raises(ValueError, match="not both"):
        rt.spawn(config=RuntimeConfig(fsdp=True), fsdp=True)


# ---------------------------------------------------------------------------
# the engine: parity + jaxpr census (single device)
# ---------------------------------------------------------------------------
def _first_compute_ag_census(rt, state, cfg, tpos=0):
    """all_gather count before the first compute primitive (the embed
    lookup's ``gather``) inside the jaxpr that holds the all-gathers."""
    key_t = rt._schedule_keys(rt.schedule)[tpos]
    b0 = make_batch(cfg, 0, 0, 4, 32)
    jaxpr = jax.make_jaxpr(
        lambda s, bb: rt._entries[key_t].jitted(s, bb))(state, b0)

    def walk(j):
        names = [e.primitive.name for e in j.eqns]
        if "all_gather" in names:
            yield names
        for e in j.eqns:
            for v in e.params.values():
                if isinstance(v, jax.extend.core.ClosedJaxpr):
                    yield from walk(v.jaxpr)
                elif hasattr(v, "eqns"):
                    yield from walk(v)

    best = None
    for names in walk(jaxpr.jaxpr):
        ag = [i for i, n in enumerate(names) if n == "all_gather"]
        comp = [i for i, n in enumerate(names)
                if n in ("gather", "dot_general")]
        if not ag or not comp:
            continue
        early = sum(1 for i in ag if i < min(comp))
        if best is None or len(ag) > best[1]:
            best = (early, len(ag))
    assert best is not None, "no jaxpr with all_gathers found"
    return best


def test_decoupled_parity_and_streaming(single_mesh):
    """The decoupled engine trains bit-identically to the fused one, and
    its phase-0 jaxpr does NOT front-load the all-gather burst: only the
    embedding's bucket is gathered before the first compute primitive,
    the rest stream in at their consuming blocks."""
    cfg, opt, sched, lay = _tiny_runtime_parts()
    rt_f = DeftRuntime(cfg, opt, sched, lay, single_mesh, fsdp=True)
    rt_d = DeftRuntime(cfg, opt, sched, lay, single_mesh,
                       config=RuntimeConfig(fsdp=True, decoupled=True))
    key = jax.random.PRNGKey(1)
    sf = rt_f.init_state(key)
    sd = rt_d.init_state(key)
    with jax.set_mesh(single_mesh):
        for i in range(sched.period):
            b = make_batch(cfg, 0, i, 4, 32)
            sf, mf = rt_f.step(i, sf, b)
            sd, md = rt_d.step(i, sd, b)
            assert float(mf["loss"]) == float(md["loss"]), i
        for bix, (a, c) in enumerate(zip(sf["pbuf"], sd["pbuf"])):
            assert jnp.array_equal(a, c), f"pbuf[{bix}] diverged"

        early_f, n_ag_f = _first_compute_ag_census(rt_f, sf, cfg)
        early_d, n_ag_d = _first_compute_ag_census(rt_d, sd, cfg)
    # both engines move the same number of all-gathers per phase (the
    # param gathers plus any stored-sync gather-backs) ...
    assert n_ag_f == n_ag_d
    assert n_ag_f >= lay.n_buckets
    # ... but fused front-loads the full param burst before the first
    # compute primitive, while decoupled issues only the embed bucket's
    assert early_f == lay.n_buckets
    assert early_d == 1
    assert rt_d.stats()["decoupled"] is True


# ---------------------------------------------------------------------------
# 4-device parity (forced host devices, subprocess)
# ---------------------------------------------------------------------------
_SCRIPT = r"""
import dataclasses
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.configs import get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import Planner, PlanRequest
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.data.pipeline import make_batch
from repro.optim.optimizers import adamw
from repro.train import (DeftRuntime, RuntimeConfig, assign_buckets,
                         build_bucket_layout, init_train_state,
                         leaf_bucket_times)

mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
cfg = reduce_for_smoke(get_config("qwen3-4b"))
opt = adamw(1e-3)
key = jax.random.PRNGKey(0)
probe = init_train_state(key, cfg, opt)
bucket_of, nb = assign_buckets(probe["params"], cfg, partition_elems=150_000)
B, S = 8, 32
times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                          HardwareModel(dp_degree=4), S, 2)
scale = 1.8 * (times.fwd_total + times.bwd_total) / times.comm_total
times = BucketTimes(times.fwd, times.bwd, tuple(c * scale for c in times.comm))
WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
res = Planner().plan(PlanRequest(times=times, walk=WALK, decoupled=True))
sched = res.schedule
assert res.ag_plan is not None and res.ag_plan.period == sched.period

lay = build_bucket_layout(probe["params"], bucket_of, nb, shard_count=2)
rt_f = DeftRuntime(cfg, opt, sched, lay, mesh, fsdp=True)
rt_d = DeftRuntime(cfg, opt, sched, lay, mesh,
                   config=RuntimeConfig(fsdp=True, decoupled=True))
sf = rt_f.init_state(key)
sd = rt_d.init_state(key)
with jax.set_mesh(mesh):
    for i in range(sched.period + 1):
        b = make_batch(cfg, 0, i, B, S)
        sf, mf = rt_f.step(i, sf, b)
        sd, md = rt_d.step(i, sd, b)
        lf, ld = float(mf["loss"]), float(md["loss"])
        assert abs(lf - ld) <= 1e-5 * max(1.0, abs(lf)), (i, lf, ld)
    diff = max(
        float(jnp.max(jnp.abs(a - c)))
        for a, c in zip(sf["pbuf"], sd["pbuf"]))
    assert diff <= 1e-5, f"pbuf diverged: {diff}"
    print(f"losses equal to 1e-5; max pbuf diff {diff:.2e}")
print("DECOUPLED_4DEV_OK")
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_decoupled_parity_on_4_devices(tmp_path):
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script), src],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DECOUPLED_4DEV_OK" in out.stdout
